"""repro.rdma: engine-pool correctness, scheduling, flow control, shutdown.

The load-bearing contracts:
  * result invariance — pooled outputs bit-equal the legacy engine and every
    pool configuration (thread count, chunking, stealing);
  * the single-thread pool IS the legacy engine configuration;
  * work stealing rescues the pathological all-one-shard batch;
  * clean shutdown completes in-flight subrequests;
  * the credit window (core.flow_control.CreditGate) bounds in-flight WRs;
  * the simulator calibrates to the pool's measured utilization.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flow_control import CreditGate
from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.rdma import (
    LookupSubrequest,
    PooledLookupService,
    RdmaEnginePool,
    VerbsTiming,
    plan_schedule,
)


def _specs():
    return (
        TableSpec("a", 500, nnz=4),
        TableSpec("b", 300, nnz=2, pooling="mean"),
        TableSpec("c", 40, nnz=1),
    )


def _setup(num_shards=4, dim=16):
    from repro.core.embedding import DisaggEmbedding

    specs = _specs()
    emb = DisaggEmbedding(specs=specs, dim=dim, num_shards=num_shards)
    params = emb.init(jax.random.key(0))
    tables = make_fused_tables(specs, dim, num_shards)
    return emb, params, tables, np.asarray(params["table"])


def _one_shard_batch(rng, tables, batch=32):
    """Every valid id lands in shard 0: field 0, ids < rows_per_shard."""
    F = len(tables.specs)
    nnz = max(t.nnz for t in tables.specs)
    span = min(tables.rows_per_shard, tables.specs[0].vocab)
    idx = rng.integers(0, span, size=(batch, F, nnz)).astype(np.int64)
    msk = np.zeros((batch, F, nnz), bool)
    msk[:, 0, :] = True
    return idx, msk


# ------------------------------------------------------------ result parity


def test_pooled_matches_oracle(rng):
    emb, params, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp)
    try:
        b = syn.recsys_batch(rng, tables.specs, 16)
        ref = emb.lookup_reference(
            params, jnp.asarray(b["indices"]), jnp.asarray(b["mask"])
        )
        out = svc.lookup(b["indices"], b["mask"])
        np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4, atol=1e-5)
    finally:
        svc.close()


@pytest.mark.parametrize("pushdown", [True, False])
def test_single_thread_pool_bit_equal_legacy(rng, pushdown):
    """num_threads=1 is the legacy RdmaEngine as one pool configuration:
    same fan-out plan, same rows, bit-identical pooled outputs."""
    _, _, tables, tnp = _setup()
    legacy = HostLookupService(tables, tnp, pushdown=pushdown)
    pool = PooledLookupService(
        tables, tnp, num_threads=1, pushdown=pushdown,
        work_stealing=False, doorbell_batch=1,
    )
    try:
        for _ in range(4):
            b = syn.recsys_batch(rng, tables.specs, 32)
            ref = legacy.lookup(b["indices"], b["mask"])
            out = pool.lookup(b["indices"], b["mask"])
            np.testing.assert_array_equal(out, ref)
            # raw f64 sums (the tier-merge form) must agree bit-exactly too
            np.testing.assert_array_equal(
                pool.lookup(b["indices"], b["mask"], mean_normalize=False),
                legacy.lookup(b["indices"], b["mask"], mean_normalize=False),
            )
    finally:
        legacy.close()
        pool.close()


def test_bit_equal_across_pool_configs(rng):
    """Thread count, chunk size, and stealing change the schedule only —
    the merged bits never move (the repro-wide result-invariance contract)."""
    _, _, tables, tnp = _setup()
    batches = [syn.recsys_batch(rng, tables.specs, 24) for _ in range(3)]
    outs = []
    for threads, chunk, steal in [
        (1, 64, False), (2, 16, True), (4, 8, True), (4, 4, False),
    ]:
        svc = PooledLookupService(
            tables, tnp, num_threads=threads,
            max_rows_per_subrequest=chunk, work_stealing=steal,
        )
        try:
            outs.append(
                [svc.lookup(b["indices"], b["mask"]) for b in batches]
            )
        finally:
            svc.close()
    for other in outs[1:]:
        for a, b in zip(outs[0], other):
            np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------- work stealing


def test_work_stealing_pathological_one_shard_batch(rng):
    """All subrequests affinity-deal to one engine; stealing must spread
    them (deterministic virtual schedule) and cut the batch latency."""
    _, _, tables, tnp = _setup()
    idx, msk = _one_shard_batch(rng, tables)
    lat = {}
    outs = {}
    for steal in (True, False):
        svc = PooledLookupService(
            tables, tnp, num_threads=4, max_rows_per_subrequest=4,
            work_stealing=steal,
        )
        try:
            outs[steal] = svc.lookup(idx, msk)
            lat[steal] = svc.virtual_latencies[0]
            if steal:
                assert svc.pool.virtual_steals > 0
                # more than one virtual engine ended up posting
                assert sum(b > 0 for b in svc.pool.virtual_busy) > 1
        finally:
            svc.close()
    np.testing.assert_array_equal(outs[True], outs[False])
    assert lat[True] < lat[False], lat
    assert lat[False] / lat[True] > 1.3, lat


def test_schedule_deterministic():
    """plan_schedule is a pure function of the subrequest list — the bench
    baselines and the calibration depend on it."""
    timing = VerbsTiming()

    def mk():
        return [
            LookupSubrequest(
                server=i % 3,
                row_ids=np.arange(4),
                bag_ids=np.zeros(4, np.int64),
                num_bags=8,
                pushdown=True,
                response_bytes=2048,
                slot=i,
            )
            for i in range(17)
        ]

    a = plan_schedule(mk(), 4, timing, doorbell_batch=4, max_inflight=8)
    b = plan_schedule(mk(), 4, timing, doorbell_batch=4, max_inflight=8)
    assert a.makespan == b.makespan
    assert a.busy == b.busy
    assert a.steals == b.steals
    assert [[r.slot for r in lane] for lane in a.assignments] == [
        [r.slot for r in lane] for lane in b.assignments
    ]


# ------------------------------------------------------------ flow control


def test_credit_gate_blocks_and_releases():
    gate = CreditGate(2)
    assert gate.acquire(2)
    assert not gate.acquire(1, timeout=0.02)  # window full
    assert gate.stalls >= 1

    t = threading.Thread(target=lambda: (time.sleep(0.05), gate.release(2)))
    t.start()
    assert gate.acquire(1, timeout=2.0)  # unblocked by the release
    t.join()
    gate.release(1)
    assert gate.inflight == 0
    assert gate.peak == 2
    with pytest.raises(ValueError):
        gate.acquire(3)  # larger than the window: would deadlock
    with pytest.raises(RuntimeError):
        gate.release(1)  # nothing held


def test_pool_respects_credit_window(rng):
    """peak in-flight never exceeds the window, and a 1-credit window still
    completes every subrequest (just serially)."""
    _, _, tables, tnp = _setup()
    svc = PooledLookupService(
        tables, tnp, num_threads=4, max_inflight=1, max_rows_per_subrequest=4
    )
    try:
        b = syn.recsys_batch(rng, tables.specs, 32)
        out = svc.lookup(b["indices"], b["mask"])
        assert svc.pool.gate.peak <= 1
        assert svc.pool.doorbell_batch == 1
    finally:
        svc.close()
    ref_svc = HostLookupService(tables, tnp)
    try:
        np.testing.assert_array_equal(
            out, ref_svc.lookup(b["indices"], b["mask"])
        )
    finally:
        ref_svc.close()


# ----------------------------------------------------------- clean shutdown


def test_clean_shutdown_with_inflight_subrequests(rng):
    """close() drains: batches submitted and not yet waited-on complete,
    their handles resolve, and the threads exit."""
    _, _, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp, num_threads=2)
    b = syn.recsys_batch(rng, tables.specs, 48)
    fused, bag, bounds, num_bags, D = svc._plan_fanout(
        b["indices"], b["mask"]
    )
    entry = 4 + D * tnp.dtype.itemsize
    handles = [
        svc.pool.submit(
            svc._shard_subrequests(fused, bag, bounds, num_bags, entry)
        )
        for _ in range(6)
    ]
    svc.close()  # in-flight work must complete, not drop
    for h in handles:
        res = h.wait(timeout=1.0)
        assert all(r is not None for r in res)
    assert all(not t.is_alive() for t in svc.pool.threads)
    with pytest.raises(RuntimeError):
        svc.pool.submit([])
    svc.close()  # idempotent


def test_failed_subrequest_raises_not_hangs(rng):
    """A WR whose server-side execution raises must resolve the batch with
    the error (not hang wait()) and leave the engine threads alive."""
    _, _, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp, num_threads=2)
    try:
        b = syn.recsys_batch(rng, tables.specs, 16)
        boom = RuntimeError("injected server failure")

        def throw(*a, **k):
            raise boom

        # Break every server-side entry point: the dedup wire protocol
        # gathers via lookup_rows/read_range, the legacy one via
        # lookup_pooled.
        orig = (
            svc.servers[0].lookup_pooled,
            svc.servers[0].lookup_rows,
            svc.servers[0].read_range,
        )
        svc.servers[0].lookup_pooled = throw
        svc.servers[0].lookup_rows = throw
        svc.servers[0].read_range = throw
        with pytest.raises(RuntimeError, match="injected server failure"):
            svc.lookup(b["indices"], b["mask"])
        (
            svc.servers[0].lookup_pooled,
            svc.servers[0].lookup_rows,
            svc.servers[0].read_range,
        ) = orig
        assert all(t.is_alive() for t in svc.pool.threads)
        # the pool still serves correctly afterwards
        out = svc.lookup(b["indices"], b["mask"])
        ref_svc = HostLookupService(tables, tnp)
        try:
            np.testing.assert_array_equal(
                out, ref_svc.lookup(b["indices"], b["mask"])
            )
        finally:
            ref_svc.close()
    finally:
        svc.close()


def test_empty_and_fully_masked_lookup(rng):
    _, _, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp)
    try:
        idx = np.zeros((4, 3, 4), np.int64)
        msk = np.zeros((4, 3, 4), bool)
        out = svc.lookup(idx, msk)
        assert out.shape == (4, 3, 16)
        np.testing.assert_array_equal(out, np.zeros_like(out))
    finally:
        svc.close()


# ------------------------------------------------- simulator calibration


def test_simulator_calibrates_to_pool_utilization(rng):
    from repro.runtime.simulator import calibrate_to_engine

    _, _, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp, num_threads=4)
    try:
        for _ in range(6):
            b = syn.recsys_batch(rng, tables.specs, 32)
            svc.lookup(b["indices"], b["mask"])
        util = svc.pool.utilization()
    finally:
        svc.close()
    assert (util >= 0).all() and (util <= 1).all()
    cal = calibrate_to_engine(util, n_batches=150, n_engines=4, n_units=4)
    assert abs(
        cal["achieved_utilization"] - cal["target_utilization"]
    ) < 0.1, cal


# --------------------------------------------------------------- reporting


def test_engine_summary_shape(rng):
    _, _, tables, tnp = _setup()
    svc = PooledLookupService(tables, tnp, num_threads=3)
    try:
        b = syn.recsys_batch(rng, tables.specs, 16)
        svc.lookup(b["indices"], b["mask"])
        s = svc.engine_summary()
    finally:
        svc.close()
    assert s["num_threads"] == 3
    assert s["batches"] == 1
    assert s["subrequests"] == sum(s["executed"])
    assert len(s["utilization"]) == 3
    assert s["p99_latency_us"] >= s["p50_latency_us"] > 0
    assert s["credit_window"]["peak"] <= s["credit_window"]["max_credits"]


def test_architecture_doc_covers_every_package():
    """Mirror of the CI docs check: docs/ARCHITECTURE.md must mention every
    src/repro/* package so the paper-to-code map cannot silently rot."""
    import pathlib

    root = pathlib.Path(__file__).resolve().parents[1]
    doc = (root / "docs" / "ARCHITECTURE.md").read_text()
    pkgs = sorted(
        p.name
        for p in (root / "src" / "repro").iterdir()
        if p.is_dir() and (p / "__init__.py").exists()
    )
    missing = [p for p in pkgs if p not in doc]
    assert not missing, f"ARCHITECTURE.md misses packages: {missing}"
