"""The Pallas kernels must agree with the MODEL-layer implementations they
replace (not just their own oracles)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding import TableSpec
from repro.core.embedding import DisaggEmbedding
from repro.kernels.ops import bag_lookup, dot_interaction_triu
from repro.models.recsys import dot_interaction


def test_bag_kernel_matches_disagg_lookup(rng):
    """kernels.bag_lookup == DisaggEmbedding sum-pooled reference (the fused
    kernel is a drop-in for the per-shard gather+pool)."""
    specs = (TableSpec("a", 120, nnz=3), TableSpec("b", 77, nnz=2))
    emb = DisaggEmbedding(specs=specs, dim=128, num_shards=1)
    params = emb.init(jax.random.key(0))
    B = 6
    idx = np.zeros((B, 2, 3), np.int32)
    msk = np.zeros((B, 2, 3), bool)
    for f, s in enumerate(specs):
        idx[:, f, : s.nnz] = rng.integers(0, s.vocab, (B, s.nnz))
        msk[:, f, : s.nnz] = True
    ref = emb.lookup_reference(params, jnp.asarray(idx), jnp.asarray(msk))
    offs = emb.sharded.field_offsets_array().astype(np.int32)
    fused = jnp.asarray(idx + offs[None, :, None])
    out = bag_lookup(params["table"], fused, jnp.asarray(msk), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_interaction_kernel_matches_model(rng):
    x = jnp.asarray(rng.normal(size=(8, 9, 32)).astype(np.float32))
    want = dot_interaction(x)
    got = dot_interaction_triu(x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
