"""Runtime layers: flow control, engine simulator, host service, serving
loop, elasticity, batcher, adaptive-cache controller."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive_cache import (
    AdaptiveCacheController,
    EmaFrequencyTracker,
    MemoryModel,
    SlidingWindowLoadMonitor,
)
from repro.core.flow_control import compare_credit_paths
from repro.core.lookup_engine import HostLookupService
from repro.core.migration import ConnectionMigrator, plan_reshard
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.data.pipeline import BucketBatcher, PrefetchIterator
from repro.runtime.elastic import reshard_params
from repro.runtime.simulator import compare_engines, compare_migration


def _specs():
    return (
        TableSpec("a", 500, nnz=4),
        TableSpec("b", 300, nnz=2, pooling="mean"),
        TableSpec("c", 40, nnz=1),
    )


def _host_setup(rng, num_shards=4, pushdown=True, **kw):
    from repro.core.embedding import DisaggEmbedding

    specs = _specs()
    emb = DisaggEmbedding(specs=specs, dim=16, num_shards=num_shards)
    params = emb.init(jax.random.key(0))
    tables = make_fused_tables(specs, 16, num_shards)
    svc = HostLookupService(tables, np.asarray(params["table"]),
                            pushdown=pushdown, **kw)
    return emb, params, tables, svc


def test_host_service_matches_oracle(rng):
    emb, params, tables, svc = _host_setup(rng)
    try:
        b = syn.recsys_batch(rng, tables.specs, 16)
        ref = emb.lookup_reference(
            params, jnp.asarray(b["indices"]), jnp.asarray(b["mask"])
        )
        out = svc.lookup(b["indices"], b["mask"])
        np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4, atol=1e-5)
    finally:
        svc.close()


def test_host_service_bag_id_broadcast(rng):
    """Regression for the bag-id layout in HostLookupService.lookup: every
    (b, f) pair owns exactly one bag id, contiguous in row-major order, and
    both pushdown modes pool identically under it (each bag's nnz entries
    must land in bag b*F+f — a broadcast bug would smear rows across bags).
    """
    emb, params, tables, svc_pd = _host_setup(rng)
    _, _, _, svc_raw = _host_setup(rng, pushdown=False)
    try:
        B, F, NNZ = 8, len(tables.specs), 4
        bag = np.broadcast_to(
            np.arange(B * F).reshape(B, F, 1), (B, F, NNZ)
        )
        assert bag.shape == (B, F, NNZ)
        # each bag id constant over its nnz axis, strictly increasing over (b,f)
        assert (bag == bag[:, :, :1]).all()
        np.testing.assert_array_equal(
            bag[:, :, 0].ravel(), np.arange(B * F)
        )
        b = syn.recsys_batch(rng, tables.specs, B)
        ref = emb.lookup_reference(
            params, jnp.asarray(b["indices"]), jnp.asarray(b["mask"])
        )
        for svc in (svc_pd, svc_raw):
            out = svc.lookup(b["indices"], b["mask"])
            assert out.shape == (B, F, 16)
            np.testing.assert_allclose(
                out, np.asarray(ref), rtol=1e-4, atol=1e-5
            )
    finally:
        svc_pd.close()
        svc_raw.close()


def test_simulator_reports_engine_utilization():
    from repro.runtime.simulator import LookupSimulator, SimConfig

    out = LookupSimulator(SimConfig(n_batches=200)).run()
    util = out["engine_utilization"]
    assert len(util) == SimConfig().n_engines
    assert all(0.0 <= u <= 1.0 for u in util)
    assert sum(out["engine_busy_s"]) > 0
    # a closed loop at inflight=8 keeps the engines meaningfully busy
    assert max(util) > 0.2


def test_pushdown_reduces_network_bytes(rng):
    """The paper's Fig-4 claim: hierarchical pooling moves fewer bytes for
    multi-hot bags than returning raw rows."""
    emb, params, tables, svc_pd = _host_setup(rng, pushdown=True)
    _, _, _, svc_raw = _host_setup(rng, pushdown=False)
    try:
        # many multi-hot hits per shard -> pushdown wins
        b = syn.recsys_batch(rng, tables.specs, 256)
        assert svc_pd.network_bytes(b["indices"], b["mask"]) < \
            svc_raw.network_bytes(b["indices"], b["mask"])
    finally:
        svc_pd.close()
        svc_raw.close()


def test_engine_simulator_matches_paper_regime():
    r = compare_engines(n_batches=300)
    assert 1.5 <= r["speedup"] <= 4.0, r  # paper: "up to 2.3x"


def test_migration_helps_under_skew():
    m = compare_migration(n_batches=300, n_units=8)
    assert m["speedup"] >= 0.95, m  # must not hurt; typically ~1.05-1.2x


def test_credit_priority_channel():
    r = compare_credit_paths(num_responses=256)
    reduction = 1 - r["flexemr"]["mean_credit_latency"] / r["strawman"]["mean_credit_latency"]
    assert reduction > 0.3, r  # paper: 35% lower credit latency


def test_connection_migrator_reassociates(rng):
    emb, params, tables, svc = _host_setup(rng, num_shards=8, num_engines=2)
    try:
        mig = ConnectionMigrator(svc, imbalance_threshold=0.5)
        b = syn.recsys_batch(rng, tables.specs, 64)
        # hammer one shard by restricting indices to its range
        svc.lookup(b["indices"], b["mask"])
        events = mig.rebalance_once()
        for ev in events:
            assert ev.reassociated
        # service still answers correctly after migration
        ref = emb.lookup_reference(params, jnp.asarray(b["indices"]), jnp.asarray(b["mask"]))
        out = svc.lookup(b["indices"], b["mask"])
        np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4, atol=1e-5)
    finally:
        svc.close()


def test_plan_reshard_reduces_imbalance():
    tables = make_fused_tables(_specs(), 16, 8)
    load = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
    plan = plan_reshard(load, tables)
    assert plan.expected_imbalance_after < plan.expected_imbalance_before


def test_elastic_reshard_lossless(rng):
    from repro.core.embedding import DisaggEmbedding

    specs = _specs()
    emb4 = DisaggEmbedding(specs=specs, dim=16, num_shards=4)
    params = emb4.init(jax.random.key(1))
    new_tables, new_params = reshard_params(emb4.sharded, params["emb"] if "emb" in params else params, 8)
    emb8 = DisaggEmbedding(specs=specs, dim=16, num_shards=8)
    b = syn.recsys_batch(rng, specs, 8)
    ref = emb4.lookup_reference(params, jnp.asarray(b["indices"]), jnp.asarray(b["mask"]))
    out = emb8.lookup_reference(
        {"table": jnp.asarray(new_params["table"])},
        jnp.asarray(b["indices"]), jnp.asarray(b["mask"]),
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- adaptive controller


def test_memory_model_tradeoff():
    mm = MemoryModel(fixed_bytes=1 << 30, bytes_per_sample=1 << 20, hbm_bytes=16 << 30)
    # bigger batch -> smaller cache budget (the Fig-7 contention)
    assert mm.cache_budget_bytes(1024) < mm.cache_budget_bytes(128)
    # bigger cache -> smaller max batch
    assert mm.max_batch_given_cache(8 << 30) < mm.max_batch_given_cache(1 << 30)


def test_controller_shrinks_under_load(rng):
    mm = MemoryModel(fixed_bytes=1 << 30, bytes_per_sample=1 << 21, hbm_bytes=16 << 30)
    ctl = AdaptiveCacheController(_specs(), 16, mm, field_replication=False,
                                  max_rows=10**9)
    for _ in range(8):
        ctl.observe(128, rng.integers(0, 800, 512))
    small_load = ctl.plan(128).capacity_rows
    for _ in range(64):
        ctl.observe(6000, rng.integers(0, 800, 512))
    high_load = ctl.plan(6000).capacity_rows
    assert high_load < small_load


def test_tracker_finds_hot_rows(rng):
    tr = EmaFrequencyTracker()
    hot = np.array([7, 13, 21])
    for _ in range(10):
        tr.update(np.concatenate([np.repeat(hot, 20), rng.integers(0, 1000, 40)]))
    top = set(tr.top_k(3).tolist())
    assert top == set(hot.tolist())
    assert tr.hot_fraction_covered(3) > 0.5


def test_sliding_window_monitor():
    mon = SlidingWindowLoadMonitor(window=4, high_frac=0.5)
    for b in (10, 10, 100, 100):
        mon.observe(b)
    assert mon.is_high_load(max_batch=110)
    assert not mon.is_high_load(max_batch=1000)


# ------------------------------------------------------------------ pipeline


def test_bucket_batcher_pads():
    b = BucketBatcher(buckets=(4, 8), max_wait=0.01)
    for i in range(5):
        b.submit({"x": np.full((2,), i, np.float32)})
    bucket, reqs = b.poll()
    assert bucket == 8 and len(reqs) == 5
    batch = b.pad_batch(reqs, bucket, {"x": ((2,), np.float32)})
    assert batch["x"].shape == (8, 2)
    assert batch["valid"].sum() == 5


def test_prefetch_iterator_restartable():
    it = PrefetchIterator(lambda step: {"step": step}, start_step=5, depth=1)
    first = next(it)
    assert first["step"] == 5
    assert it.state()["step"] == 6
    it.close()
