"""Wire-dedup bench: §3.1.1 temporal locality applied at the wire layer.

The A/B: the SAME zipf lookup stream served by ``PooledLookupService`` with
the unique-row wire protocol on vs off (``dedup``), in fig-4(a) raw-row mode
(``pushdown=False``) — the transfer format where duplicated references cost
duplicated payload, so the dedup lever is isolated from the pushdown lever.
Zipf skew controls the duplicate fraction (``dup_frac = 1 - uniques /
references``): at high skew most of a batch's references hit the same hot
head rows, which is exactly the regime the paper's temporal-locality
argument lives in.

Four measurements:

  1. skew sweep — per-alpha duplicate fraction, wire-byte reduction
     (engine ``wire_response_bytes`` counters, dedup off / on), and virtual
     p99 lookup-latency speedup.  The headline gates, at the highest skew:
     ``byte_reduction >= 1.4x`` and ``p99_speedup >= 1.2x`` (fewer, larger
     WRs: fewer t_post/t_server charges and range-coalesced hot heads).
     Also reported (not gated): ``dedup_vs_pushdown_bytes``, the
     unique-row protocol's response bytes against the fig-4(b) per-bag
     partials it REPLACES as the serving default — >1 means dedup beats
     pushdown at that skew, <1 quantifies the trade on low-duplicate
     traffic.
  2. invariance grid — bit-equal outputs across {dedup on/off} x
     {legacy, pooled} x pipeline depth {1, 2, 4} x hedge {off, forced}:
     the dedup layer changes *what the wire carries*, never *what lookups
     return*.
  3. cross-batch coalescing — a depth-2 pipelined replay in wire-emulation
     mode with the hedge forced: pipelined batches borrow hot rows still in
     flight for their predecessor (``coalesced_rows > 0``), hedged
     duplicates race and lose cleanly, and the scores stay bit-equal.
  4. simulator cross-check — ``runtime.simulator.compare_dedup`` fed the
     *measured* duplicate fraction must predict the measured byte reduction
     within 10% (relative); the residual is the range WRs' dropped per-row
     tags, which the closed-form model does not price.

``run(smoke=True)`` shrinks the stream so ``benchmarks/run.py --smoke`` and
the CI entry ``python -m benchmarks.dedup_bench --smoke`` finish in seconds
while still gating all four.
"""
from __future__ import annotations

import argparse
import collections
import time

import numpy as np

from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.rdma import PooledLookupService
from repro.rdma.verbs import VerbsTiming
from repro.runtime.simulator import compare_dedup

ALPHAS = (1.05, 1.6)  # low vs high zipf skew (gates apply at the highest)
DEPTHS = (1, 2, 4)


def _stream(rng, specs, n_batches: int, batch: int, alpha: float):
    return [
        syn.recsys_batch(rng, specs, batch, alpha=alpha)
        for _ in range(n_batches)
    ]


def _dup_frac(stream, tables) -> float:
    """Duplicate fraction of valid row references across the stream."""
    offs = tables.field_offsets_array()
    refs = uniques = 0
    for b in stream:
        fused = b["indices"].astype(np.int64) + offs[None, :, None]
        valid = fused[b["mask"]]
        refs += len(valid)
        uniques += len(np.unique(valid))
    return 1.0 - uniques / max(1, refs)


def _serve(tables, tnp, stream, dedup, depth=1, hedge=None,
           emulate=False, legacy=False):
    """Replay the stream keeping ``depth`` lookups in flight; returns
    (outs, engine summary or None)."""
    if legacy:
        svc = HostLookupService(tables, tnp, pushdown=False, dedup=dedup)
    else:
        svc = PooledLookupService(
            tables, tnp, num_threads=4, pushdown=False, dedup=dedup,
            timing=VerbsTiming(t_server=2e-4) if emulate else None,
            emulate_wire=emulate,
        )
    outs = [None] * len(stream)
    try:
        pending: collections.deque = collections.deque()
        for i, b in enumerate(stream):
            pending.append(
                (i, svc.lookup_async(b["indices"], b["mask"],
                                     hedge_timeout=hedge))
            )
            if len(pending) >= depth:
                j, h = pending.popleft()
                outs[j] = h.wait()
        while pending:
            j, h = pending.popleft()
            outs[j] = h.wait()
        summary = svc.engine_summary() if not legacy else None
        coalesced = getattr(svc, "coalesced_rows", 0)
    finally:
        svc.close()
    return outs, summary, coalesced


def run(seed: int = 0, smoke: bool = False) -> dict:
    t_start = time.perf_counter()
    n_batches = 12 if smoke else 48
    batch = 64
    specs = (
        TableSpec("hist", 60_000, nnz=8),
        TableSpec("item", 20_000, nnz=4),
        TableSpec("geo", 5_000, nnz=1, pooling="mean"),
    )
    dim, shards = 32, 8
    tables = make_fused_tables(specs, dim, shards)
    rng = np.random.default_rng(seed)
    tnp = (0.05 * rng.normal(size=(tables.total_rows, dim))).astype(
        np.float32
    )
    streams = {a: _stream(rng, specs, n_batches, batch, a) for a in ALPHAS}

    # ------------------------------------------ 1. skew sweep: bytes + p99
    dup_frac, byte_red, p99_speed, range_wrs = {}, {}, {}, {}
    dedup_vs_pushdown = {}
    pd_pricer = HostLookupService(tables, tnp, pushdown=True)
    dd_pricer = PooledLookupService(tables, tnp, dedup=True)
    try:
        for a, stream in streams.items():
            dup_frac[a] = _dup_frac(stream, tables)
            _, s_off, _ = _serve(tables, tnp, stream, dedup=False)
            _, s_on, _ = _serve(tables, tnp, stream, dedup=True)
            byte_red[a] = s_off["wire_response_bytes"] / max(
                1, s_on["wire_response_bytes"]
            )
            p99_speed[a] = s_off["p99_latency_us"] / max(
                1e-9, s_on["p99_latency_us"]
            )
            range_wrs[a] = s_on["range_wrs"]
            # The trade-off the serving default takes: unique-row responses
            # REPLACE fig-4(b) per-bag partials.  >1 means dedup also beats
            # pushdown at this skew; <1 quantifies what the default gives
            # up on low-duplicate traffic (not gated — workload-dependent).
            pd = dd = 0
            for b in stream:
                pd += pd_pricer.network_bytes(b["indices"], b["mask"])
                dd += dd_pricer.network_bytes(b["indices"], b["mask"])
            dedup_vs_pushdown[a] = pd / max(1, dd)
    finally:
        pd_pricer.close()
        dd_pricer.close()
    hi = max(ALPHAS)

    # --------------------------------------------------- 2. invariance grid
    grid_stream = streams[hi][: max(6, n_batches // 2)]
    # ref IS the (dedup=False, legacy) cell of the grid.
    ref, _, _ = _serve(tables, tnp, grid_stream, dedup=False, legacy=True)
    bit_equal = True
    leg, _, _ = _serve(tables, tnp, grid_stream, dedup=True, legacy=True)
    bit_equal &= all(np.array_equal(x, y) for x, y in zip(leg, ref))
    for dedup in (False, True):
        for depth in DEPTHS:
            for hedge in (None, 0.0):
                outs, _, _ = _serve(
                    tables, tnp, grid_stream, dedup=dedup, depth=depth,
                    hedge=hedge,
                )
                bit_equal &= all(
                    np.array_equal(x, y) for x, y in zip(outs, ref)
                )

    # --------------------- 3. cross-batch coalescing + forced hedge (slow)
    co_stream = streams[hi][: 4 if smoke else 8]
    co_out, co_sum, coalesced = _serve(
        tables, tnp, co_stream, dedup=True, depth=2, hedge=0.0, emulate=True,
    )
    bit_equal &= all(np.array_equal(x, y) for x, y in zip(co_out, ref))

    # ----------------------------------------------- 4. simulator crosscheck
    sim = compare_dedup(
        dup_frac=dup_frac[hi], n_batches=150 if smoke else 400
    )
    sim_err = abs(sim["byte_reduction"] - byte_red[hi]) / byte_red[hi]

    return {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "dup_frac": dup_frac,
        "byte_reduction": byte_red,
        "p99_speedup": p99_speed,
        "dedup_vs_pushdown_bytes": dedup_vs_pushdown,
        "range_wrs": range_wrs,
        "bit_equal": bit_equal,
        "coalesced_rows": coalesced,
        "hedged_wrs": co_sum["hedged"],
        "hedge_cancelled_wrs": co_sum["hedge_cancelled"],
        "sim_byte_reduction": sim["byte_reduction"],
        "sim_rel_err": sim_err,
        "byte_reduction_high_skew": byte_red[hi],
        "p99_speedup_high_skew": p99_speed[hi],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale configuration (CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    opts = ap.parse_args(argv)
    out = run(seed=opts.seed, smoke=opts.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bit_equal"]:
        raise SystemExit(
            "dedup invariance VIOLATED: outputs moved with the wire protocol"
        )
    if out["byte_reduction_high_skew"] < 1.4:
        raise SystemExit(
            f"wire-byte reduction regressed: "
            f"{out['byte_reduction_high_skew']:.2f}x < 1.4x at high skew"
        )
    if out["p99_speedup_high_skew"] < 1.2:
        raise SystemExit(
            f"p99 speedup regressed: "
            f"{out['p99_speedup_high_skew']:.2f}x < 1.2x at high skew"
        )
    if out["coalesced_rows"] <= 0:
        raise SystemExit(
            "in-flight coalescing dead: pipelined batches borrowed no rows"
        )
    if out["sim_rel_err"] > 0.10:
        raise SystemExit(
            f"simulator dedup model off by {out['sim_rel_err']:.1%} "
            "(> 10% of the measured byte reduction)"
        )


if __name__ == "__main__":
    main()
