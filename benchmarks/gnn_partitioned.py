"""Hillclimb variant for graphsage ogb_products: node-partitioned aggregation
(vs the baseline's replicated-node psum).  Pipeline contract: edges arrive
partitioned by destination owner (standard graph partitioning); nodes are
padded to the device count."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import CellBuild
from repro.configs.graphsage_reddit import SHAPES, _cfg
from repro.models import gnn as G
from repro.optim import optimizers as opt_lib
from repro.optim import sharding_rules as opt_specs
from repro.utils import round_up

SDS = jax.ShapeDtypeStruct


def build_partitioned_cell(mesh, multi_pod: bool, pad_feat: int | None = None,
                           comm_dtype=jnp.bfloat16) -> CellBuild:
    info = SHAPES["ogb_products"]
    cfg = _cfg(info)
    if pad_feat:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_in=pad_feat)
    all_axes = tuple(mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in all_axes]))
    N = round_up(info["n_nodes"], n_dev)
    E = round_up(info["n_edges"], n_dev)

    optimizer = opt_lib.make_adam(1e-3)
    pshapes = G.abstract_params(cfg)
    pspecs = G.param_specs(cfg)
    sshapes = jax.eval_shape(optimizer.init, pshapes)
    sspecs = opt_specs.adam_state_specs(pspecs, pshapes)

    batch_abs = {
        "feats": SDS((N, cfg.d_in), jnp.float32),
        "edges": SDS((E, 2), jnp.int32),
        "edge_mask": SDS((E,), jnp.bool_),
        "labels": SDS((N,), jnp.int32),
        "label_mask": SDS((N,), jnp.float32),
    }
    node_spec = P(all_axes, None)
    bspecs = {
        "feats": node_spec,
        "edges": P(all_axes, None),
        "edge_mask": P(all_axes),
        "labels": P(all_axes),
        "label_mask": P(all_axes),
    }

    def step(params, opt_state, batch):
        def loss_fn(p):
            logits = G.forward_full_graph_partitioned(
                cfg, p, batch["feats"], batch["edges"], batch["edge_mask"],
                mesh, comm_dtype=comm_dtype,
            )
            return G.node_ce_loss(logits, batch["labels"], batch["label_mask"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    return CellBuild(
        "train_step",
        step,
        (pshapes, sshapes, batch_abs),
        (pspecs, sspecs, bspecs),
        donate_argnums=(0, 1),
    )
