"""Open-loop latency-under-load bench: the latency-vs-offered-load curve.

Every other bench drives ``FlexEMRServer`` closed-loop, which structurally
hides queueing delay — the client slows down exactly when the server
saturates.  This bench drives the same wire-emulated serving stack
(pipeline_bench's workload: zipf DLRM lookups, ~2 ms emulated server+wire
per subrequest, jit'd dense ranker) with the ``repro.loadgen`` open-loop
harness and sweeps the offered rate across the knee:

  1. **capacity calibration** — closed-loop replay measures the saturated
     service rate; sweep points are fractions of it.
  2. **latency-vs-load sweep** — seeded Poisson arrivals at 0.5x / 0.7x /
     1.4x capacity (more points off smoke).  Gates: p99 at 0.7x stays
     within bound of the 0.5x baseline (below the knee the curve is flat),
     and p99 at 1.4x strictly inflates past the 0.7x point (past the knee
     queueing dominates — the thing closed-loop benches cannot see).
  3. **SLO / burn-rate alerting** — a flash-crowd run (0.5x base with a
     mid-run spike to ~3x capacity concentrated on one hot sparse field)
     must fire the multi-window burn-rate alert; a plain 0.5x run under
     the same objective must stay alert-free.
  4. **attribution exactness** — every run's ``serve.attr.coverage`` (the
     request-weighted attributed/end-to-end ratio) within 1%; the
     flash-crowd run traces, and ``tools/trace_export.py``'s attribution
     report over the exported file must agree.

``run(smoke=True)`` is the CI entry (`benchmarks/run.py --smoke`,
``python -m benchmarks.loadgen_bench --smoke``).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from benchmarks.obs_bench import _trace_export
from benchmarks.pipeline_bench import _build, _request_stream

BATCH = 32


def _make_server(cfg, params, tables, timing, tracer=None, registry=None,
                 slo=None):
    import jax.numpy as jnp

    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    server = FlexEMRServer(
        cfg, params, tables,
        num_engines=4, pipeline_depth=2, hedge_timeout=None,
        track_bytes=False, timing=timing, emulate_wire=True,
        batcher=BucketBatcher(buckets=(BATCH,), max_wait=0.0005),
        tracer=tracer, registry=registry, slo=slo,
    )
    server._dense(
        jnp.zeros((BATCH, cfg.num_fields, cfg.embed_dim), np.float32),
        jnp.zeros((BATCH, cfg.n_dense), np.float32),
    ).block_until_ready()
    return server


def _capacity(cfg, params, tables, timing, n_batches: int) -> float:
    """Closed-loop saturated service rate (requests/s): everything queued
    up front, stepped to drain — the denominator of the sweep fractions."""
    rng = np.random.default_rng(0)
    reqs = _request_stream(rng, cfg, n_batches, BATCH)
    server = _make_server(cfg, params, tables, timing)
    try:
        for r in reqs:
            server.submit(r)
        t0 = time.perf_counter()
        while server.step() is not None:
            pass
        wall = time.perf_counter() - t0
    finally:
        server.close()
    return len(reqs) / wall


def _open_loop_run(cfg, params, tables, timing, schedule, crowd=None,
                   seed=0, slo=None, tracer=None, max_events=None):
    """One open-loop run on a fresh server + registry; returns stats."""
    from repro.loadgen import (OpenLoopDriver, OpenLoopGenerator,
                               RecsysPayloadFactory)
    from repro.obs.metrics import MetricsRegistry

    gen = OpenLoopGenerator(
        schedule,
        RecsysPayloadFactory(cfg.tables, cfg.n_dense, crowd=crowd),
        seed=seed,
        max_events=max_events,
    )
    events = gen.events()
    registry = MetricsRegistry()
    server = _make_server(cfg, params, tables, timing, tracer=tracer,
                          registry=registry, slo=slo)
    try:
        driver_stats = OpenLoopDriver().run(server, events)
    finally:
        server.close()
    snap = registry.snapshot()
    return {
        "events": len(events),
        "driver": driver_stats,
        "p50_s": 1e-3 * snap["serve.p50_latency_ms"],
        "p99_s": 1e-3 * snap["serve.p99_latency_ms"],
        "queue_wait_p99_s": snap["serve.queue_wait.p99"],
        "attr_coverage": snap["serve.attr.coverage"],
        "snapshot": snap,
    }


def run(smoke: bool = False) -> dict:
    from repro.loadgen import constant, flash_crowd
    from repro.obs.slo import SloMonitor, SloObjective
    from repro.obs.trace import Tracer

    cfg, params, tables, timing = _build(0)
    horizon = 1.2 if smoke else 3.0
    cap_batches = 40 if smoke else 120
    capacity = _capacity(cfg, params, tables, timing, cap_batches)

    # ---- latency-vs-offered-load sweep across the knee
    fracs = (0.5, 0.7, 1.4) if smoke else (0.3, 0.5, 0.7, 0.9, 1.1, 1.4)
    curve = []
    by_frac = {}
    for i, frac in enumerate(fracs):
        r = _open_loop_run(
            cfg, params, tables, timing,
            constant(frac * capacity, horizon), seed=100 + i,
        )
        by_frac[frac] = r
        curve.append({
            "offered_frac": frac,
            "offered_qps": frac * capacity,
            "achieved_qps": r["driver"]["achieved_qps"],
            "p50_ms": 1e3 * r["p50_s"],
            "p99_ms": 1e3 * r["p99_s"],
            "queue_wait_p99_ms": 1e3 * r["queue_wait_p99_s"],
        })
    p99_low = by_frac[0.5]["p99_s"]
    p99_knee = by_frac[0.7]["p99_s"]
    p99_over = by_frac[1.4]["p99_s"]
    # Below the knee the curve is flat (generous absolute floor so CPU
    # noise on a starved container can't flake the gate); past it the tail
    # must strictly inflate — the whole point of driving open-loop.
    below_knee_ok = p99_knee <= max(5.0 * p99_low, 0.15)
    past_knee_inflates = p99_over >= 1.5 * p99_knee

    # ---- SLO objective calibrated off the below-knee baseline.  The
    # floor is generous (well above any below-knee tail, far below the
    # seconds-scale backlog a flash crowd builds) so host noise on a
    # loaded CI container can't fire the half-load control run.
    objective = SloObjective(
        latency_target_s=max(6.0 * p99_low, 0.25),
        target=0.99,
        fast_window_s=0.25,
        slow_window_s=1.0,
        burn_threshold=10.0,
        min_samples=20,
    )

    # Plain 0.5x run under the objective: must stay alert-free.
    slo_base = SloMonitor(objective)
    _open_loop_run(
        cfg, params, tables, timing, constant(0.5 * capacity, horizon),
        seed=7, slo=slo_base,
    )

    # Flash crowd: 0.5x base, mid-run spike to ~3x capacity with 90% of
    # spike arrivals hammering one hot id set in field 0 — overload plus
    # RecShard-style per-field skew.  The burn-rate alert must fire.
    spike_sched, crowd = flash_crowd(
        base_qps=0.5 * capacity,
        spike_qps=3.0 * capacity,
        duration=horizon + 0.4,
        spike_t0=0.4 * horizon,
        spike_t1=0.4 * horizon + (0.5 if smoke else 1.0),
        field=0,
        hot_ids=tuple(range(16)),
    )
    slo_crowd = SloMonitor(objective)
    tracer = Tracer()
    crowd_run = _open_loop_run(
        cfg, params, tables, timing, spike_sched, crowd=crowd, seed=13,
        slo=slo_crowd, tracer=tracer,
    )

    # ---- attribution exactness: registry coverage + the trace-side table
    coverage_errs = [abs(r["attr_coverage"] - 1.0) for r in by_frac.values()]
    coverage_errs.append(abs(crowd_run["attr_coverage"] - 1.0))
    te = _trace_export()
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        trace_path = f.name
    tracer.save(trace_path)
    trace = te.load(trace_path)
    trace_problems = te.validate(trace)
    attr_report = te.attribution(trace)
    coverage_errs.append(abs(attr_report["coverage"] - 1.0))

    out = {
        "us_per_call": 0.0,
        "capacity_qps": capacity,
        "curve": curve,
        "p99_low_ms": 1e3 * p99_low,
        "p99_knee_ms": 1e3 * p99_knee,
        "p99_overload_ms": 1e3 * p99_over,
        "below_knee_ok": bool(below_knee_ok),
        "past_knee_inflates": bool(past_knee_inflates),
        "slo_latency_target_ms": 1e3 * objective.latency_target_s,
        "base_alerts": slo_base.alerts_fired,
        "crowd_alerts": slo_crowd.alerts_fired,
        "alert_fires_under_crowd": slo_crowd.alerts_fired >= 1,
        "alert_silent_at_half_load": slo_base.alerts_fired == 0,
        "attr_coverage_err": max(coverage_errs),
        "attr_coverage_ok": max(coverage_errs) <= 0.01,
        "trace_valid": not trace_problems,
        "goodput_rps": crowd_run["snapshot"]["slo.goodput_rps"],
        "throughput_rps": crowd_run["snapshot"]["slo.throughput_rps"],
    }
    gates = {
        "below_knee_ok": out["below_knee_ok"],
        "past_knee_inflates": out["past_knee_inflates"],
        "alert_fires_under_crowd": out["alert_fires_under_crowd"],
        "alert_silent_at_half_load": out["alert_silent_at_half_load"],
        "attr_coverage_ok": out["attr_coverage_ok"],
        "trace_valid": out["trace_valid"],
    }
    failed = [k for k, ok in gates.items() if not ok]
    out["gates_ok"] = not failed
    out["gates_failed"] = failed
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run with the same gates")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    print(f"capacity: {out['capacity_qps']:.0f} req/s")
    print(f"{'offered':>9s} {'qps':>8s} {'p50_ms':>8s} {'p99_ms':>9s} "
          f"{'qwait_p99':>10s}")
    for pt in out["curve"]:
        print(f"{pt['offered_frac']:8.1f}x {pt['offered_qps']:8.0f} "
              f"{pt['p50_ms']:8.2f} {pt['p99_ms']:9.2f} "
              f"{pt['queue_wait_p99_ms']:10.2f}")
    print(f"slo target {out['slo_latency_target_ms']:.1f} ms; "
          f"base alerts {out['base_alerts']}, "
          f"crowd alerts {out['crowd_alerts']}; "
          f"goodput {out['goodput_rps']:.0f}/{out['throughput_rps']:.0f} rps")
    print(f"attribution coverage err {out['attr_coverage_err']:.2%}")
    for k in ("below_knee_ok", "past_knee_inflates",
              "alert_fires_under_crowd", "alert_silent_at_half_load",
              "attr_coverage_ok", "trace_valid"):
        print(f"{'PASS' if out[k] else 'FAIL'}: {k}")
    return 0 if out["gates_ok"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
