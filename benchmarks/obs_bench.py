"""Observability overhead bench: tracing + metrics on vs off, same stream.

The obs contract has three legs, all gated here on the pipeline-bench
serving workload (wire-emulated, latency-bound — the regime the tracer is
for):

  1. **bit-equality** — scores with the tracer + registry fully on are
     bit-identical to the plain run: observability watches the hot path,
     never perturbs it.
  2. **overhead <= 5%** — wall clock of the fully-instrumented run (Tracer
     recording every span/instant, registry providers registered, one
     snapshot at the end) within 5% of the uninstrumented run.  Both sides
     take the best of ``reps`` alternating replays so host noise hits both
     equally.
  3. **sum-consistency** — the trace and the metrics snapshot agree: summed
     ``lookup_stall`` span time == ``serve.lookup_seconds``, summed
     ``dense`` == ``serve.dense_seconds``, summed ``credit_stall`` ==
     ``rdma.pool.virtual_credit_stall_s``, ``steal`` instants ==
     ``rdma.pool.virtual_steals`` — and the exported Chrome trace passes
     ``tools/trace_export.py`` validation (nesting, no negative durations).

``run(smoke=True)`` is the CI entry (`benchmarks/run.py --smoke`,
``python -m benchmarks.obs_bench --smoke``).
"""
from __future__ import annotations

import argparse
import gc
import importlib.util
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.pipeline_bench import _build, _request_stream


def _trace_export():
    """Import tools/trace_export.py (not a package) by path."""
    path = pathlib.Path(__file__).resolve().parents[1] / "tools" / \
        "trace_export.py"
    spec = importlib.util.spec_from_file_location("trace_export", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _serve(cfg, params, tables, timing, reqs, batch, tracer=None,
           registry=None, snapshot=False):
    """One replay; returns (scores, wall_s, server-metrics, engine, snap)."""
    import jax.numpy as jnp

    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    server = FlexEMRServer(
        cfg, params, tables,
        num_engines=4, pipeline_depth=2, hedge_timeout=None,
        track_bytes=False, timing=timing, emulate_wire=True,
        batcher=BucketBatcher(buckets=(batch,), max_wait=0.0005),
        tracer=tracer, registry=registry,
    )
    try:
        server._dense(
            jnp.zeros((batch, cfg.num_fields, cfg.embed_dim), np.float32),
            jnp.zeros((batch, cfg.n_dense), np.float32),
        ).block_until_ready()
        for r in reqs:
            server.submit(r)
        outs = []
        # GC pauses inside the ~100 ms measured window are the dominant
        # noise term on a single-core host (several ms each, landing on
        # one side of the A/B at random): collect up front, then keep the
        # collector off for the timed region.
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        while True:
            o = server.step()
            if o is None:
                break
            outs.append(o["scores"])
        snap = registry.snapshot() if snapshot and registry else None
        wall = time.perf_counter() - t0
        gc.enable()
        metrics = {
            "lookup_seconds": server.metrics.lookup_seconds,
            "dense_seconds": server.metrics.dense_seconds,
            "hedges": server.metrics.hedges,
        }
        engine = server.engine_summary()
    finally:
        server.close()
    return outs, wall, metrics, engine, snap


def _close(a: float, b: float, rel: float = 1e-6, abs_: float = 1e-9) -> bool:
    return abs(a - b) <= max(abs_, rel * max(abs(a), abs(b)))


def run(seed: int = 0, smoke: bool = False, trace_out: str | None = None
        ) -> dict:
    from repro.obs import MetricsRegistry, Tracer

    t_start = time.perf_counter()
    n_batches = 10 if smoke else 24
    batch = 32
    cfg, params, tables, timing = _build(seed)
    rng = np.random.default_rng(seed)
    reqs = _request_stream(rng, cfg, n_batches, batch)

    # ------------------------------------------- overhead A/B (best-of-reps)
    # Each rep is an adjacent off/on pair and the overhead estimate is the
    # MINIMUM of the per-pair ratios.  Host noise on a shared single-core
    # container comes in sustained bursts (cgroup throttling) that slow
    # both halves of a pair proportionally — the pair ratio stays clean
    # even when no individual wall time does, where the ratio of global
    # minima flakes whenever every on-rep lands inside a burst.
    reps = 5
    wall_off = wall_on = float("inf")
    scores_off = scores_on = None
    traced = None  # (tracer, metrics, engine, snapshot) of the best on-run
    pair_ratios = []
    for _ in range(reps):
        outs, w_off, _, _, _ = _serve(cfg, params, tables, timing, reqs,
                                      batch)
        if w_off < wall_off:
            wall_off, scores_off = w_off, outs
        tracer, registry = Tracer(), MetricsRegistry()
        outs, w_on, metrics, engine, snap = _serve(
            cfg, params, tables, timing, reqs, batch,
            tracer=tracer, registry=registry, snapshot=True,
        )
        if w_on < wall_on:
            wall_on, scores_on = w_on, outs
            traced = (tracer, metrics, engine, snap)
        pair_ratios.append(w_on / w_off)
    overhead = min(pair_ratios) - 1.0
    bit_equal = len(scores_off) == len(scores_on) and all(
        np.array_equal(a, b) for a, b in zip(scores_off, scores_on)
    )
    tracer, metrics, engine, snap = traced

    # ------------------------------------------------------- sum-consistency
    def span_sum(name):
        return sum(e["dur"] for e in tracer.events(name=name))

    checks = {
        "lookup_stall_vs_lookup_seconds": _close(
            span_sum("lookup_stall"), metrics["lookup_seconds"]
        ),
        "dense_vs_dense_seconds": _close(
            span_sum("dense"), metrics["dense_seconds"]
        ),
        "credit_stall_vs_virtual": _close(
            span_sum("credit_stall"), engine["virtual_credit_stall_s"]
        ),
        "steals_vs_virtual": (
            len(tracer.events(name="steal")) == engine["virtual_steals"]
        ),
        "hedge_arm_vs_hedges": (
            len(tracer.events(name="hedge_arm")) == metrics["hedges"]
        ),
        "snapshot_has_namespaces": all(
            any(k.startswith(p) for k in snap)
            for p in ("serve.", "tier.", "rdma.pool.")
        ),
    }
    sum_consistent = all(checks.values())

    # ------------------------------------- export round-trip + validation
    te = _trace_export()
    if trace_out is None:
        tmp = tempfile.NamedTemporaryFile(
            "w", suffix=".trace.json", delete=False
        )
        trace_path = tmp.name
        tmp.close()
    else:
        trace_path = trace_out
    tracer.save(trace_path)
    loaded = te.load(trace_path)
    problems = te.validate(loaded)
    stages = te.summarize(loaded)
    if trace_out is None:
        pathlib.Path(trace_path).unlink()

    return {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "overhead_frac": overhead,
        "bit_equal": bit_equal,
        "events": len(tracer),
        "dropped_events": tracer.dropped,
        "sum_consistent": sum_consistent,
        "sum_checks": checks,
        "trace_valid": not problems,
        "trace_problems": problems,
        "stages": len(stages),
        "snapshot_keys": len(snap),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale configuration (CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", type=str, default=None,
                    help="also keep the instrumented run's Chrome trace "
                    "here (default: validated then discarded)")
    opts = ap.parse_args(argv)
    out = run(seed=opts.seed, smoke=opts.smoke, trace_out=opts.trace_out)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bit_equal"]:
        raise SystemExit(
            "obs invariance VIOLATED: scores moved with tracing enabled"
        )
    if not out["sum_consistent"]:
        bad = [k for k, ok in out["sum_checks"].items() if not ok]
        raise SystemExit(f"trace/metrics sum-consistency failed: {bad}")
    if not out["trace_valid"]:
        raise SystemExit(f"trace export invalid: {out['trace_problems']}")
    if out["overhead_frac"] > 0.05:
        raise SystemExit(
            f"observability overhead {out['overhead_frac']:.1%} > 5% gate"
        )


if __name__ == "__main__":
    main()
