"""Overload bench: deadline admission, retry ladder, brownout — with gates.

The PR-7 loadgen bench established the failure mode: past the saturation
knee an open-loop arrival process retires *every* request, all of them
late — goodput collapses while throughput stays pinned.  This bench gates
the three overload responses layered on top of that harness:

  1. **goodput no-collapse (admission A/B)** — the same 1.4x-capacity
     Poisson ramp with per-request deadlines, shed-off vs shed-on
     (:class:`repro.runtime.admission.AdmissionController`).  With
     admission on, unmeetable requests fast-fail at submit and the
     survivors retire on time: ``slo.goodput_rps`` must be >= 1.3x the
     shed-off run's.  The shed-off run is the control — its goodput
     collapse is the disease being treated.
  2. **bounded retry amplification (storm + ladder)** — a chaos straggler
     storm under 1.2x open-loop load with the WR retry/timeout ladder on
     (``RetryPolicy(budget_frac=0.25)``).  Gates: the ladder actually
     fires (virtual timeouts re-fly storm-slowed WRs), total charged
     retries stay within the budget fraction of primary traffic, the
     chaos firing log is bit-identical across two runs (seeded backoff,
     admit-count firing), and nothing hangs (all requests retire, no
     watchdog restores, nothing parked, no leaked engine threads).
  3. **bit-equality / flag-coverage grid (brownout)** — chaos_bench's
     deterministic explicit-drive replay with a mid-stream shard drop,
     swept over pipeline depth {1,2,4} x wire dedup {on,off} x degrade
     policy {strict, degrade} against a fault-free reference.  ``strict``
     cells (park-until-restore) must be fully bit-equal with zero
     degraded flags; ``degrade`` cells (answer cold rows from the cache
     tier's best partial) may diverge ONLY on requests whose retire
     carried the ``degraded`` flag — every unflagged request bit-equal.

``run(smoke=True)`` is the CI entry (`benchmarks/run.py --smoke`,
``python -m benchmarks.overload_bench --smoke``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.pipeline_bench import _build, _request_stream

BATCH = 32
DEADLINE_S = 0.25  # per-request latency budget for the goodput A/B
GOODPUT_RATIO_GATE = 1.3  # shed-on goodput >= gate * shed-off goodput
RETRY_BUDGET_FRAC = 0.25  # storm-run retry budget (fraction of primaries)
GRID_DEPTHS = (1, 2, 4)


def _make_server(cfg, params, tables, timing, registry=None, slo=None,
                 admission=None, retry_policy=None, chaos=None):
    import jax.numpy as jnp

    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    server = FlexEMRServer(
        cfg, params, tables,
        num_engines=4, pipeline_depth=2, hedge_timeout=None,
        track_bytes=False, timing=timing, emulate_wire=True,
        batcher=BucketBatcher(buckets=(BATCH,), max_wait=0.0005),
        registry=registry, slo=slo, chaos=chaos,
        admission=admission, retry_policy=retry_policy,
    )
    server._dense(
        jnp.zeros((BATCH, cfg.num_fields, cfg.embed_dim), np.float32),
        jnp.zeros((BATCH, cfg.n_dense), np.float32),
    ).block_until_ready()
    return server


def _capacity(cfg, params, tables, timing, n_batches: int) -> float:
    """Closed-loop saturated service rate (the 1.x multipliers' base)."""
    rng = np.random.default_rng(0)
    reqs = _request_stream(rng, cfg, n_batches, BATCH)
    server = _make_server(cfg, params, tables, timing)
    try:
        for r in reqs:
            server.submit(r)
        t0 = time.perf_counter()
        while server.step() is not None:
            pass
        wall = time.perf_counter() - t0
    finally:
        server.close()
    return len(reqs) / wall


def _overload_run(cfg, params, tables, timing, qps, horizon, seed,
                  deadline_s=None, admission=None, retry_policy=None,
                  chaos=None):
    """One open-loop run; returns driver stats + summaries for the gates."""
    from repro.loadgen import (OpenLoopDriver, OpenLoopGenerator,
                               RecsysPayloadFactory, constant)
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.slo import SloMonitor, SloObjective

    gen = OpenLoopGenerator(
        constant(qps, horizon),
        RecsysPayloadFactory(cfg.tables, cfg.n_dense),
        seed=seed,
        deadline_s=deadline_s,
    )
    events = gen.events()
    registry = MetricsRegistry()
    slo = None
    if deadline_s is not None:
        slo = SloMonitor(SloObjective(
            latency_target_s=deadline_s, target=0.99,
            fast_window_s=0.25, slow_window_s=1.0,
            burn_threshold=10.0, min_samples=20,
        ))
    server = _make_server(
        cfg, params, tables, timing, registry=registry, slo=slo,
        admission=admission, retry_policy=retry_policy, chaos=chaos,
    )
    try:
        driver_stats = OpenLoopDriver().run(server, events)
    finally:
        server.close()
    snap = registry.snapshot()
    return {
        "events": len(events),
        "driver": driver_stats,
        "snapshot": snap,
        "goodput_rps": snap["slo.goodput_rps"] if slo is not None else 0.0,
        "admission": None if admission is None else admission.summary(),
        "retry": server.service.retry_summary(),
        # engine/chaos summaries read post-close so leaked_threads is final
        "engine": server.engine_summary(),
        "chaos": None if chaos is None else chaos.summary(),
    }


def _storm_schedule():
    """Two straggler storms (latency_mult 8 > the ladder's timeout_mult 4,
    so every storm-slowed WR is timeout-eligible)."""
    from repro.chaos import FaultSchedule, FaultSpec

    return FaultSchedule(faults=(
        FaultSpec("straggler_storm", at_batch=4, target=1,
                  duration_batches=4, latency_mult=8.0),
        FaultSpec("straggler_storm", at_batch=12, target=2,
                  duration_batches=4, latency_mult=8.0),
    ), seed=0)


# ---------------------------------------------------------------- part C grid


def _drop_schedule(n_batches: int):
    from repro.chaos import FaultSchedule, FaultSpec

    return FaultSchedule(faults=(
        FaultSpec("drop_shard", at_batch=max(2, n_batches // 3), target=0,
                  duration_batches=2),
    ), seed=0)


def _grid_serve(cfg, params, tables, reqs, batch, depth, dedup, policy,
                chaos=None):
    """Deterministic explicit-drive replay (chaos_bench idiom); returns
    (scores per batch, degraded flags per batch, summaries)."""
    from repro.core.adaptive_cache import AdaptiveCacheController, MemoryModel
    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    controller = AdaptiveCacheController(
        cfg.tables, cfg.embed_dim,
        MemoryModel(fixed_bytes=1 << 20, bytes_per_sample=1 << 10,
                    hbm_bytes=1 << 28),
        field_replication=False, max_rows=1024,
    )
    server = FlexEMRServer(
        cfg, params, tables, controller=controller,
        cache_refresh_every=4, pipeline_depth=depth, hedge_timeout=0.05,
        batcher=BucketBatcher(buckets=(batch,), max_wait=0.005),
        dedup=dedup, degrade_policy=policy, chaos=chaos,
    )
    try:
        for r in reqs:
            server.submit(r)
        outs, flags = [], []
        while True:
            while len(server._pipeline) < server.pipeline_depth \
                    and server._admit_next():
                pass
            if not server._pipeline:
                break
            out = server._retire_oldest()
            outs.append(np.asarray(out["scores"]))
            flags.append(list(out["degraded"]))
        chaos_summary = None if chaos is None else chaos.summary()
        degraded = server._degraded_summary()
        engine = server.engine_summary()
    finally:
        server.close()
    return outs, flags, engine, chaos_summary, degraded


def _flatten(outs, flags):
    """Per-request score stream + flag stream.  Each batch's scores cover
    the padded bucket; the degraded flag list covers exactly the valid
    requests, so slicing by it drops the pad rows.  Flattening makes the
    comparison immune to batch-boundary drift (a wall-clock partial batch
    shifts every later batch but not the request order)."""
    scores = np.concatenate(
        [np.asarray(o)[:len(f)] for o, f in zip(outs, flags)]
    )
    return scores, [b for f in flags for b in f]


def _cell_verdict(ref_scores, scores, flags):
    """Per-request comparison of one grid cell against the reference.

    Returns (bit_equal, mismatches, flagged, uncovered): uncovered counts
    requests whose scores moved WITHOUT the degraded flag — must be zero
    under every policy."""
    if ref_scores.shape != scores.shape:
        return False, -1, -1, -1  # lost/extra requests: hard fail
    diff = ref_scores != scores
    per_req = diff if diff.ndim == 1 \
        else diff.reshape(diff.shape[0], -1).any(axis=1)
    mismatches = int(per_req.sum())
    flagged = int(sum(flags))
    uncovered = int(sum(
        1 for j in range(len(per_req)) if per_req[j] and not flags[j]
    ))
    return mismatches == 0, mismatches, flagged, uncovered


def _grid(smoke: bool) -> dict:
    from benchmarks.chaos_bench import _build as _build_small
    from benchmarks.chaos_bench import _request_stream as _stream_small
    from repro.chaos import ChaosInjector

    n_batches = 12 if smoke else 30
    batch = 16
    cfg, params, tables = _build_small(0)
    rng = np.random.default_rng(0)
    reqs = _stream_small(rng, cfg, n_batches, batch)

    refs = {}
    for dedup in (True, False):
        outs, flags, _, _, _ = _grid_serve(
            cfg, params, tables, reqs, batch, 2, dedup, "strict"
        )
        refs[dedup], _ = _flatten(outs, flags)

    cells = []
    for depth in GRID_DEPTHS:
        for dedup in (True, False):
            for policy in ("strict", "degrade"):
                injector = ChaosInjector(
                    _drop_schedule(n_batches), watchdog_s=10.0
                )
                outs, flags, engine, summ, degraded = _grid_serve(
                    cfg, params, tables, reqs, batch, depth, dedup, policy,
                    chaos=injector,
                )
                scores, fl = _flatten(outs, flags)
                bit_equal, mism, flg, uncov = _cell_verdict(
                    refs[dedup], scores, fl
                )
                hangs_ok = (
                    len(fl) == len(reqs)
                    and summ["wall"]["forced_restores"] == 0
                    and engine["parked_now"] == 0
                    and summ["active_drops"] == []
                    and engine["leaked_threads"] == 0
                )
                cells.append({
                    "depth": depth, "dedup": dedup, "policy": policy,
                    "fired": summ["faults_fired"],
                    "bit_equal": bit_equal,
                    "mismatched_requests": mism,
                    "flagged_requests": flg,
                    "uncovered_mismatches": uncov,
                    "degraded_rows": degraded["rows"],
                    "zero_hangs": hangs_ok,
                })

    strict_cells = [c for c in cells if c["policy"] == "strict"]
    degrade_cells = [c for c in cells if c["policy"] == "degrade"]
    strict_ok = all(
        c["bit_equal"] and c["flagged_requests"] == 0 for c in strict_cells
    )
    # Degrade may diverge, but only on flagged requests — and at least one
    # cell must actually exercise the brownout (flags + partial rows).
    coverage_ok = all(c["uncovered_mismatches"] == 0 for c in degrade_cells)
    brownout_exercised = any(
        c["flagged_requests"] > 0 and c["degraded_rows"] > 0
        for c in degrade_cells
    )
    return {
        "cells": cells,
        "grid_cells": len(cells),
        "grid_faults_fired": min(c["fired"] for c in cells),
        "grid_strict_bit_equal": bool(strict_ok),
        "grid_flags_cover_mismatches": bool(coverage_ok),
        "grid_brownout_exercised": bool(brownout_exercised),
        "grid_zero_hangs": bool(all(c["zero_hangs"] for c in cells)),
        "grid_degraded_requests": sum(
            c["flagged_requests"] for c in degrade_cells
        ),
    }


def run(smoke: bool = False) -> dict:
    from repro.chaos import ChaosInjector
    from repro.rdma.verbs import RetryPolicy
    from repro.runtime.admission import AdmissionController

    t_start = time.perf_counter()
    cfg, params, tables, timing = _build(0)
    horizon = 2.0 if smoke else 4.0
    cap_batches = 40 if smoke else 120
    capacity = _capacity(cfg, params, tables, timing, cap_batches)
    overload_qps = 1.4 * capacity

    # ---- part A: goodput A/B at 1.4x capacity, admission off vs on
    off = _overload_run(
        cfg, params, tables, timing, overload_qps, horizon, seed=100,
        deadline_s=DEADLINE_S,
    )
    on = _overload_run(
        cfg, params, tables, timing, overload_qps, horizon, seed=100,
        deadline_s=DEADLINE_S, admission=AdmissionController(),
    )
    goodput_off = off["goodput_rps"]
    goodput_on = on["goodput_rps"]
    goodput_ratio = goodput_on / max(goodput_off, 1e-9)
    adm = on["admission"]

    # ---- part B: straggler storm at 1.2x with the retry ladder on (twice,
    # for the firing-log determinism gate)
    policy = RetryPolicy(budget_frac=RETRY_BUDGET_FRAC, seed=0)
    storms = []
    for _ in range(2):
        storms.append(_overload_run(
            cfg, params, tables, timing, 1.2 * capacity, horizon, seed=200,
            retry_policy=policy, chaos=ChaosInjector(_storm_schedule()),
        ))
    storm, storm2 = storms
    retry = storm["retry"]
    storm_hangs_ok = (
        storm["driver"]["shed"] == 0
        and storm["chaos"]["wall"]["forced_restores"] == 0
        and storm["engine"]["parked_now"] == 0
        and storm["engine"]["leaked_threads"] == 0
        and storm["chaos"]["active_drops"] == []
    )
    firing_deterministic = (
        storm["chaos"]["firing_log"] == storm2["chaos"]["firing_log"]
        and storm["chaos"]["faults_fired"] == len(_storm_schedule().faults)
    )

    # ---- part C: bit-equality / flag-coverage grid
    grid = _grid(smoke)

    out = {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "capacity_qps": capacity,
        "deadline_ms": 1e3 * DEADLINE_S,
        # part A
        "goodput_off_rps": goodput_off,
        "goodput_on_rps": goodput_on,
        "goodput_ratio": goodput_ratio,
        "shed": adm["shed"],
        "shed_frac": adm["shed_frac"],
        "shed_expired": adm["shed_expired"],
        "shed_queue_full": adm["shed_queue_full"],
        "shed_deadline": adm["shed_deadline"],
        "depth_shrinks": adm["depth_shrinks"],
        "admitted": adm["admitted"],
        # part B
        "retry_budget_frac": retry["budget_frac"],
        "retry_charged": retry["charged"],
        "retry_denied": retry["denied"],
        "retry_timeouts": retry["timeouts"],
        "retry_attempts": retry["attempts"],
        "retry_amplification": retry["amplification"],
        "storm_zero_hangs": bool(storm_hangs_ok),
        "storm_firing_deterministic": bool(firing_deterministic),
        # part C
        **{k: v for k, v in grid.items() if k != "cells"},
        "grid": grid["cells"],
    }
    gates = {
        "goodput_no_collapse": goodput_ratio >= GOODPUT_RATIO_GATE,
        "admission_sheds": adm["shed"] > 0,
        "retry_ladder_fires": retry["timeouts"] >= 1,
        "retry_within_budget":
            retry["amplification"] <= RETRY_BUDGET_FRAC + 1e-9,
        "storm_zero_hangs": out["storm_zero_hangs"],
        "storm_firing_deterministic": out["storm_firing_deterministic"],
        "grid_strict_bit_equal": out["grid_strict_bit_equal"],
        "grid_flags_cover_mismatches": out["grid_flags_cover_mismatches"],
        "grid_brownout_exercised": out["grid_brownout_exercised"],
        "grid_zero_hangs": out["grid_zero_hangs"],
    }
    failed = [k for k, ok in gates.items() if not ok]
    out["gates_ok"] = not failed
    out["gates_failed"] = failed
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run with the same gates")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    print(f"capacity: {out['capacity_qps']:.0f} req/s "
          f"(deadline {out['deadline_ms']:.0f} ms)")
    print(f"goodput shed-off {out['goodput_off_rps']:.0f} rps, "
          f"shed-on {out['goodput_on_rps']:.0f} rps "
          f"({out['goodput_ratio']:.2f}x); shed {out['shed']} "
          f"({out['shed_frac']:.0%}: expired {out['shed_expired']} "
          f"queue_full {out['shed_queue_full']} "
          f"deadline {out['shed_deadline']}), "
          f"depth_shrinks {out['depth_shrinks']}")
    print(f"storm: {out['retry_timeouts']} timeouts, "
          f"{out['retry_attempts']} backoff attempts, "
          f"{out['retry_charged']}/{out['retry_denied']} charged/denied, "
          f"amplification {out['retry_amplification']:.3f} "
          f"(budget {out['retry_budget_frac']:.2f})")
    print(f"grid: {out['grid_cells']} cells, "
          f"{out['grid_degraded_requests']} degraded requests flagged")
    for c in out["grid"]:
        print(f"  depth={c['depth']} dedup={str(c['dedup']):5s} "
              f"{c['policy']:7s} fired={c['fired']} "
              f"mism={c['mismatched_requests']} "
              f"flagged={c['flagged_requests']} "
              f"uncovered={c['uncovered_mismatches']}")
    for k in ("goodput_no_collapse", "admission_sheds", "retry_ladder_fires",
              "retry_within_budget", "storm_zero_hangs",
              "storm_firing_deterministic", "grid_strict_bit_equal",
              "grid_flags_cover_mismatches", "grid_brownout_exercised",
              "grid_zero_hangs"):
        ok = k not in out["gates_failed"]
        print(f"{'PASS' if ok else 'FAIL'}: {k}")
    return 0 if out["gates_ok"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
