"""Microbench of the Pallas-kernel call sites vs their XLA baselines (CPU
wall-time of the reference paths; the kernels themselves are TPU-target and
validated in interpret mode — wall time here tracks the XLA baseline the
kernels replace, giving the §Perf baseline numbers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as REF


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return 1e6 * (time.perf_counter() - t0) / iters


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    table = jnp.asarray(rng.normal(size=(200_000, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 200_000, 8192 * 4).astype(np.int32))
    w = jnp.asarray(np.ones(8192 * 4, np.float32))
    bag = jax.jit(lambda t, i, ww: REF.embedding_bag_ref(t, i, ww, 8192))
    out["embedding_bag_us"] = _time(bag, table, idx, w)

    x = jnp.asarray(rng.normal(size=(1024, 27, 64)).astype(np.float32))
    dot = jax.jit(REF.dot_interaction_ref)
    out["dot_interaction_us"] = _time(dot, x)

    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)).astype(np.float32))
    fa = jax.jit(lambda q, k, v: REF.flash_attention_ref(q, k, v, True))
    out["attention_us"] = _time(fa, q, k, k)
    out["us_per_call"] = sum(out.values())
    return out


if __name__ == "__main__":
    print(run())
