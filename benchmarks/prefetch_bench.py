"""Prefetch bench: spatial-locality piggyback vs the demand-only hotcache.

Three measurements, one per layer of the repro/prefetch subsystem:

  1. equal-capacity A/B — the same co-occurrence-enabled zipf stream (a
     persistent pattern pool with periodic churn, data.synthetic.
     CooccurrenceWorkload) served by two identical tiered stacks, one with a
     PrefetchEngine piggybacking on the swap-in channel.  Headlines: the
     cache-hit-rate lift, the miss-path wire-byte reduction, and the
     prefetch-useful rate (fraction of speculative rows that served a hit
     before eviction).  The bench also *verifies the invariance contract*:
     pooled outputs are bit-equal with prefetch on and off.
  2. kernel — the Pallas top-k-neighbor-select vs its jnp oracle on a
     serving-shaped candidate tile (equality + timing).
  3. simulator sweep — runtime.simulator.compare_prefetch: closed-loop
     throughput vs prefetch accuracy at a fixed piggyback budget, in the
     byte-bound regime where speculation must pay for its own bytes.

``run(smoke=True)`` shrinks every dimension so `benchmarks/run.py --smoke`
exercises the whole path in seconds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.embedding import DisaggEmbedding
from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data.synthetic import CooccurrenceWorkload
from repro.hotcache.miss_path import TieredLookupService
from repro.hotcache.policy import AdmissionPolicy
from repro.prefetch import (
    CooccurrenceMiner,
    PrefetchEngine,
    PrefetchPolicy,
    topk_neighbor_select,
    topk_neighbor_select_ref,
)
from repro.runtime.simulator import compare_prefetch


def _serve_stream(tables, table_np, batches, prefetch: bool):
    """One tiered stack over the stream; returns (stats, outputs, us/call)."""
    svc = HostLookupService(tables, table_np)
    prefetcher = None
    if prefetch:
        prefetcher = PrefetchEngine(
            CooccurrenceMiner(list_len=16, max_rows=16_384, decay=0.99),
            PrefetchPolicy(k_neighbors=12, byte_budget=1 << 18, min_score=1.0),
        )
    tiered = TieredLookupService(
        svc,
        num_slots=4096,
        policy=AdmissionPolicy(admission_threshold=3.0, max_swap_in=1024),
        refresh_every=2,
        prefetcher=prefetcher,
    )
    outs = []
    t0 = time.perf_counter()
    try:
        for b in batches:
            outs.append(tiered.lookup(b["indices"], b["mask"]))
    finally:
        svc.close()
    us = (time.perf_counter() - t0) / max(1, len(batches)) * 1e6
    return tiered.stats, outs, us


def run(seed: int = 0, smoke: bool = False) -> dict:
    n_batches = 36 if smoke else 80
    specs = (
        TableSpec("hist", 40_000, nnz=8),
        TableSpec("item", 10_000, nnz=4),
    )
    dim, shards = 32, 4
    emb = DisaggEmbedding(specs=specs, dim=dim, num_shards=shards)
    params = emb.init(jax.random.key(seed))
    tables = make_fused_tables(specs, dim, shards)
    table_np = np.asarray(params["table"])

    workload = CooccurrenceWorkload(
        specs,
        batch=64,
        alpha=1.03,  # weak temporal skew: the spatial structure is the prize
        cooccur_frac=0.7,
        pool_size=128 if smoke else 256,
        pattern_alpha=1.15,
        drift_every=8,  # catalog churn keeps re-warming pressure on
        drift_frac=0.15,
        seed=seed + 7,
    )
    batches = [workload.next_batch() for _ in range(n_batches)]

    base, out_base, _ = _serve_stream(tables, table_np, batches, prefetch=False)
    pf, out_pf, us = _serve_stream(tables, table_np, batches, prefetch=True)
    bit_equal = all(
        np.array_equal(a, b) for a, b in zip(out_base, out_pf)
    )

    # ---------------------------------------------------------------- kernel
    rng = np.random.default_rng(seed)
    M, L, K = (32, 128, 8) if smoke else (256, 128, 8)
    scores = rng.normal(size=(M, L)).astype(np.float32)
    scores[rng.random((M, L)) < 0.3] = -np.inf
    t0 = time.perf_counter()
    kv, ki = topk_neighbor_select(scores, K, interpret=True)
    kernel_us = (time.perf_counter() - t0) * 1e6
    rv, ri = topk_neighbor_select_ref(scores, K)
    kernel_ok = bool(
        np.array_equal(np.asarray(kv), np.asarray(rv))
        and np.array_equal(np.asarray(ki), np.asarray(ri))
    )

    # ------------------------------------------------------------- simulator
    sim = compare_prefetch(
        n_batches=200 if smoke else 1000,
        bytes_per_subrequest=524288.0,
    )

    total_base = base.bytes_network + base.bytes_swap_in
    total_pf = pf.bytes_network + pf.bytes_swap_in + pf.bytes_prefetch
    return {
        "us_per_call": us,
        "hit_rate_base": base.hit_rate,
        "hit_rate_prefetch": pf.hit_rate,
        "hit_delta": pf.hit_rate - base.hit_rate,
        "miss_bytes_base": base.bytes_network,
        "miss_bytes_prefetch": pf.bytes_network,
        "miss_bytes_reduction": base.bytes_network / max(1, pf.bytes_network),
        "total_bytes_ratio": total_pf / max(1, total_base),
        "bytes_prefetch": pf.bytes_prefetch,
        "prefetch_issued": pf.prefetch_issued,
        "prefetch_useful_rate": pf.prefetch_useful_rate,
        "bit_equal": bit_equal,
        "kernel_us": kernel_us,
        "kernel_matches_ref": kernel_ok,
        "sim_speedup_at_best_accuracy": sim["speedup_at_best_accuracy"],
        "sim_overhead_at_zero_accuracy": sim["overhead_at_zero_accuracy"],
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
