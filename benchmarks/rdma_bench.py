"""RDMA engine bench: lookup-latency scaling of the §3.2 engine pool.

Four measurements, one per layer of the repro/rdma subsystem:

  1. thread sweep — the SAME zipf lookup stream served by PooledLookupService
     at 1/2/4 engine threads (fixed traffic, fixed subrequest chunking):
     virtual p50/p99 lookup latency per thread count, and the headline
     ``p99_speedup`` from 1 thread to the widest pool (the ISSUE's >=1.5x
     acceptance quantity).  Pooled outputs are verified bit-equal across
     every thread count and against the legacy HostLookupService — the
     engine changes *when subrequests move*, never *what lookups return*.
  2. fanout sweep — the widest pool at several ``max_rows_per_subrequest``
     settings.  Over-fine chunks pay per-WR post overhead, so with uniform
     traffic (shards >= threads already gives the pool parallelism) the
     coarse end wins; fine chunks earn their cost under skew, where they
     are the steal granularity — which is measurement 3.
  3. work stealing — a pathological all-one-shard stream (every subrequest
     affinity-deals to one engine) with stealing on vs off.
  4. calibration — runtime.simulator.calibrate_to_engine fits the
     simulator's t_post to the pool's measured per-thread utilization, so
     the Fig-8 sweeps extrapolate from the engine we actually run.

``run(smoke=True)`` shrinks every dimension so `benchmarks/run.py --smoke`
(and the CI entry ``python -m benchmarks.rdma_bench --smoke``) exercises the
whole path in seconds.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.rdma import PooledLookupService
from repro.runtime.simulator import calibrate_to_engine

THREAD_SWEEP = (1, 2, 4)
CHUNK_SWEEP = (128, 32, 8)


def _serve_stream(
    tables, table_np, batches, threads, chunk=32, work_stealing=True
):
    """Run the stream through one pool config; returns (outs, summary, us)."""
    svc = PooledLookupService(
        tables,
        table_np,
        num_threads=threads,
        max_rows_per_subrequest=chunk,
        work_stealing=work_stealing,
    )
    t0 = time.perf_counter()
    try:
        outs = [svc.lookup(b["indices"], b["mask"]) for b in batches]
        summary = svc.engine_summary()
        util = svc.pool.utilization()
    finally:
        svc.close()
    us = (time.perf_counter() - t0) / max(1, len(batches)) * 1e6
    return outs, summary, util, us


def _one_shard_batches(rng, tables, n_batches, batch=64):
    """Batches whose every valid id lives in shard 0 (field 0, small ids)."""
    F = len(tables.specs)
    nnz = max(t.nnz for t in tables.specs)
    out = []
    span = min(tables.rows_per_shard, tables.specs[0].vocab)
    for _ in range(n_batches):
        idx = rng.integers(0, span, size=(batch, F, nnz)).astype(np.int64)
        msk = np.zeros((batch, F, nnz), bool)
        msk[:, 0, :] = True
        out.append({"indices": idx, "mask": msk})
    return out


def run(seed: int = 0, smoke: bool = False) -> dict:
    n_batches = 30 if smoke else 120
    specs = (
        TableSpec("hist", 60_000, nnz=8),
        TableSpec("item", 20_000, nnz=4),
        TableSpec("geo", 5_000, nnz=1, pooling="mean"),
    )
    dim, shards = 32, 8
    tables = make_fused_tables(specs, dim, shards)
    rng = np.random.default_rng(seed)
    table_np = (0.05 * rng.normal(size=(tables.total_rows, dim))).astype(
        np.float32
    )
    batches = [syn.recsys_batch(rng, specs, 64) for _ in range(n_batches)]

    # ----------------------------------------------- 1. thread sweep (fixed)
    legacy = HostLookupService(tables, table_np)
    try:
        ref = [legacy.lookup(b["indices"], b["mask"]) for b in batches]
    finally:
        legacy.close()

    sweep: dict[int, dict] = {}
    bit_equal = True
    util_widest = None
    us = 0.0
    for T in THREAD_SWEEP:
        outs, summary, util, us = _serve_stream(tables, table_np, batches, T)
        bit_equal &= all(np.array_equal(a, b) for a, b in zip(outs, ref))
        sweep[T] = summary
        util_widest = util
    t_lo, t_hi = THREAD_SWEEP[0], THREAD_SWEEP[-1]
    p99_speedup = sweep[t_lo]["p99_latency_us"] / max(
        1e-9, sweep[t_hi]["p99_latency_us"]
    )
    p50_speedup = sweep[t_lo]["p50_latency_us"] / max(
        1e-9, sweep[t_hi]["p50_latency_us"]
    )

    # ------------------------------------------------------ 2. fanout sweep
    fanout = {}
    for chunk in CHUNK_SWEEP:
        _, summary, _, _ = _serve_stream(
            tables, table_np, batches[: max(8, n_batches // 3)], t_hi,
            chunk=chunk,
        )
        fanout[chunk] = summary["p99_latency_us"]

    # ------------------------------------------ 3. work-stealing pathological
    patho = _one_shard_batches(rng, tables, max(8, n_batches // 3))
    p_out, p_steal, _, _ = _serve_stream(
        tables, table_np, patho, t_hi, chunk=8, work_stealing=True
    )
    n_out, p_nosteal, _, _ = _serve_stream(
        tables, table_np, patho, t_hi, chunk=8, work_stealing=False
    )
    bit_equal &= all(np.array_equal(a, b) for a, b in zip(p_out, n_out))
    steal_speedup = p_nosteal["p99_latency_us"] / max(
        1e-9, p_steal["p99_latency_us"]
    )

    # --------------------------------------------------------- 4. calibration
    cal = calibrate_to_engine(
        util_widest,
        n_batches=150 if smoke else 400,
        n_engines=t_hi,
        n_units=t_hi,
    )

    return {
        "us_per_call": us,
        "p50_latency_us": {T: s["p50_latency_us"] for T, s in sweep.items()},
        "p99_latency_us": {T: s["p99_latency_us"] for T, s in sweep.items()},
        "p50_speedup": p50_speedup,
        "p99_speedup": p99_speedup,
        "bit_equal": bit_equal,
        "virtual_steals": sweep[t_hi]["virtual_steals"],
        "fanout_p99_us": fanout,
        "steal_speedup": steal_speedup,
        "steal_steals": p_steal["virtual_steals"],
        "utilization": [float(u) for u in util_widest],
        "credit_window": sweep[t_hi]["credit_window"],
        "calibrated_t_post_us": 1e6 * cal["t_post"],
        "calibration_target_util": cal["target_utilization"],
        "calibration_achieved_util": cal["achieved_utilization"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale configuration (CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    opts = ap.parse_args(argv)
    out = run(seed=opts.seed, smoke=opts.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bit_equal"]:
        raise SystemExit("result-invariance VIOLATED across engine configs")
    if out["p99_speedup"] < 1.5:
        raise SystemExit(
            f"p99 scaling regressed: {out['p99_speedup']:.2f}x < 1.5x"
        )


if __name__ == "__main__":
    main()
