"""Paper Fig 7: a static GPU embedding cache starves the NN of batch memory;
FlexEMR's adaptive cache preserves the maximum batch size.

Uses the MemoryModel (capacity accounting, §3.1.1) + a measured zipf hit-rate
curve: for each static cache size, the supported batch shrinks and throughput
= batch / t_batch(batch, hit_rate) drops; the adaptive controller picks the
cache size that fits the *current* load, recovering the large batch under
pressure while keeping the latency win when idle.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.adaptive_cache import (
    AdaptiveCacheController,
    EmaFrequencyTracker,
    MemoryModel,
)
from repro.core.sharding import TableSpec
from repro.data import synthetic as syn

TABLES = tuple(TableSpec(f"t{i}", 500_000, nnz=4) for i in range(8))
DIM = 64


def hit_rate_curve(rng, cache_rows_list) -> dict[int, float]:
    tr = EmaFrequencyTracker()
    total = sum(t.vocab for t in TABLES)
    for _ in range(20):
        b = syn.recsys_batch(rng, TABLES, 2048)
        offs = np.cumsum([0] + [t.vocab for t in TABLES])[:-1]
        fused = b["indices"].astype(np.int64) + offs[None, :, None]
        tr.update(fused[b["mask"]])
    return {k: tr.hot_fraction_covered(k) for k in cache_rows_list}


def run(seed: int = 0) -> dict:
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    # v5e-like: 16 GiB; dense NN needs 4 GiB fixed + 1.5 MiB/sample
    mm = MemoryModel(fixed_bytes=4 << 30, bytes_per_sample=3 << 19,
                     hbm_bytes=16 << 30)
    bytes_per_row = DIM * 4
    sizes = [0, 1 << 20, 4 << 20, 8 << 20, 16 << 20, 28 << 20]  # rows
    hits = hit_rate_curve(rng, sizes)

    t_lookup_remote = 1.0  # relative cost units per missed row
    t_lookup_local = 0.1
    rows_per_sample = sum(t.nnz for t in TABLES)

    def throughput(batch, cache_rows):
        if batch <= 0:
            return 0.0
        h = hits[cache_rows]
        t_sample = rows_per_sample * (
            h * t_lookup_local + (1 - h) * t_lookup_remote
        ) + 20.0  # dense NN cost per sample
        return batch / (t_sample * batch / batch)  # = batch / t_sample

    static = {}
    for c in sizes:
        max_b = mm.max_batch_given_cache(c * bytes_per_row)
        static[c] = {
            "max_batch": max_b,
            "throughput": throughput(max_b, c),
            "hit_rate": hits[c],
        }

    # adaptive: under high load choose the cache the budget allows
    ctl = AdaptiveCacheController(
        TABLES, DIM, mm, field_replication=False, max_rows=max(sizes)
    )
    for _ in range(8):
        b = syn.recsys_batch(rng, TABLES, 4096)
        offs = np.cumsum([0] + [t.vocab for t in TABLES])[:-1]
        fused = b["indices"].astype(np.int64) + offs[None, :, None]
        ctl.observe(4096, fused[b["mask"]])
    plan_hi = ctl.plan(mm.max_batch_given_cache(0))
    adapt_rows = min(sizes, key=lambda s: abs(s - plan_hi.capacity_rows))
    adaptive_tp = throughput(mm.max_batch_given_cache(adapt_rows * bytes_per_row),
                             adapt_rows)

    best_static_large_cache = static[sizes[-1]]["throughput"]
    return {
        "us_per_call": 1e6 * (time.perf_counter() - t0),
        "static": static,
        "adaptive_rows": adapt_rows,
        "adaptive_throughput": adaptive_tp,
        "speedup_vs_large_static": adaptive_tp / max(best_static_large_cache, 1e-9),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1, default=str))
