"""Cross-batch pipelining bench: FlexEMRServer at pipeline_depth 1 vs 2 vs 4.

The §3.2 follow-on A/B: the SAME zipf serving stream (the Fig-7 workload
shape: skewed DLRM lookups + a jit'd dense ranker) replayed through
``runtime.serving.FlexEMRServer`` at several ``pipeline_depth`` settings.
At depth 1 the loop is closed — lookup N, dense N, lookup N+1 — so the
engine pool idles through every dense stage; at depth 2+ batch N+1's miss
subrequests are posted before batch N's dense stage runs and the pool
fetches them while the ranker computes.

The engine runs in **wire-emulation** mode (``emulate_wire=True``): each
work request occupies its engine thread for its virtual wire + server time
as a real, GIL-free sleep, making the lookup *latency*-bound exactly like a
genuine RDMA deployment — which is the regime where cross-batch pipelining
pays (DisaggRec's observation), and the only honest way to measure overlap
on an RNIC-less, CPU-starved container where dense compute and gather
compute would otherwise fight for the same two cores (zero-sum).

Four measurements:

  1. depth sweep — wall-clock throughput at depth 1/2/4; the headline
     ``pipeline_speedup`` is depth-2 over depth-1 (the ISSUE's >=1.3x
     acceptance quantity).  Scores are verified BIT-EQUAL across every
     depth: pipelining changes *when* bytes move, never *what* scores come
     back (f64 tier merge + issue-order pool merge).
  2. hedge A/B — depth 2 with the pool-side straggler hedge forced on
     every batch (``hedge_timeout=0``) vs off: bit-equal scores, and the
     duplicate/cancellation counters from the engine summary showing
     cancel-the-loser at work.
  3. stall accounting — ranker-thread lookup stall per depth: the pipeline
     converts lookup wait into overlap, so stall shrinks as depth grows.
  4. calibration — ``runtime.simulator.calibrate_to_engine`` fits t_post to
     the depth-2 run's measured per-thread engine utilization (the virtual
     layer carries QP/credit state across the pipelined batches), and
     ``compare_pipeline`` reports the simulator's predicted depth speedup.
     The gate: achieved within 10% of the measured utilization (relative).

``run(smoke=True)`` shrinks the stream so `benchmarks/run.py --smoke` and
the CI entry ``python -m benchmarks.pipeline_bench --smoke`` finish in
seconds while still gating the >=1.3x speedup and the depth invariance.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

DEPTHS = (1, 2, 4)


def _build(seed: int):
    import jax

    from repro.core.sharding import TableSpec, make_fused_tables
    from repro.models import recsys as R
    from repro.rdma.verbs import VerbsTiming

    tables_spec = (
        TableSpec("hist", 200_000, nnz=4),
        TableSpec("item", 100_000, nnz=2),
        TableSpec("geo", 4_000, nnz=1, pooling="mean"),
    )
    cfg = R.RecsysConfig(
        name="pipeline-bench", arch="dlrm", tables=tables_spec,
        embed_dim=64, n_dense=13,
        bottom_mlp=(1024, 64), mlp=(2048, 1024, 256),
    )
    params = R.init_params(cfg, jax.random.key(seed))
    tables = make_fused_tables(cfg.tables, cfg.embed_dim, 8)
    # Latency-bound lookups: ~2ms of emulated server+wire per subrequest.
    timing = VerbsTiming(t_server=2e-3)
    return cfg, params, tables, timing


def _request_stream(rng, cfg, n_batches: int, batch: int) -> list[dict]:
    from repro.data import synthetic as syn

    reqs = []
    for _ in range(n_batches * batch):
        b = syn.recsys_batch(rng, cfg.tables, 1, n_dense=cfg.n_dense)
        reqs.append(
            {"indices": b["indices"][0], "mask": b["mask"][0],
             "dense": b["dense"][0]}
        )
    return reqs


def _serve(cfg, params, tables, timing, reqs, batch, depth,
           hedge_timeout=None):
    """Replay the stream at one pipeline depth; returns (scores, stats)."""
    import jax.numpy as jnp

    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    server = FlexEMRServer(
        cfg, params, tables,
        num_engines=4, pipeline_depth=depth, hedge_timeout=hedge_timeout,
        track_bytes=False, timing=timing, emulate_wire=True,
        batcher=BucketBatcher(buckets=(batch,), max_wait=0.0005),
    )
    try:
        # Warm the jit outside the timed region.
        server._dense(
            jnp.zeros((batch, cfg.num_fields, cfg.embed_dim), np.float32),
            jnp.zeros((batch, cfg.n_dense), np.float32),
        ).block_until_ready()
        for r in reqs:
            server.submit(r)
        outs = []
        t0 = time.perf_counter()
        while True:
            o = server.step()
            if o is None:
                break
            outs.append(o["scores"])
        wall = time.perf_counter() - t0
        stats = {
            "wall_s": wall,
            "throughput_rps": len(reqs) / wall,
            "lookup_stall_s": server.metrics.lookup_seconds,
            "dense_s": server.metrics.dense_seconds,
            "hedged_batches": server.metrics.hedges,
            "engine": server.engine_summary(),
            "utilization": server.service.pool.utilization().tolist(),
        }
    finally:
        server.close()
    return outs, stats


def run(seed: int = 0, smoke: bool = False) -> dict:
    from repro.runtime.simulator import calibrate_to_engine, compare_pipeline

    t_start = time.perf_counter()
    n_batches = 16 if smoke else 32
    batch = 32
    cfg, params, tables, timing = _build(seed)
    rng = np.random.default_rng(seed)
    reqs = _request_stream(rng, cfg, n_batches, batch)

    # --------------------------------------------------- 1. depth sweep A/B
    # Each depth is measured `reps` times and scored by its best run:
    # the lookup side is deterministic virtual-time sleeps, but the dense
    # stage shares cores with whatever else the host is doing, and a single
    # noisy run must not flip the CI gate.  Depths alternate within a rep
    # so drift hits both sides of the ratio equally.  (A machine with <2
    # usable cores cannot overlap dense with the gather wakeups at all —
    # CI runs this on a dedicated runner, where the measured margin is
    # ~1.5-1.7x against the 1.3x floor.)
    reps = 3
    sweep: dict[int, dict] = {}
    scores: dict[int, list] = {}
    for _ in range(reps):
        for d in DEPTHS:
            outs, stats = _serve(
                cfg, params, tables, timing, reqs, batch, d
            )
            if d not in sweep or stats["wall_s"] < sweep[d]["wall_s"]:
                sweep[d] = stats
            scores[d] = outs
    bit_equal = all(
        np.array_equal(a, b)
        for d in DEPTHS[1:]
        for a, b in zip(scores[DEPTHS[0]], scores[d])
    )
    speedup = (
        sweep[2]["throughput_rps"] / max(1e-9, sweep[1]["throughput_rps"])
    )

    # ------------------------------------------------ 2. hedge cancel-loser
    hedge_reqs = reqs[: (8 if smoke else 12) * batch]
    h_on, s_on = _serve(
        cfg, params, tables, timing, hedge_reqs, batch, 2, hedge_timeout=0.0
    )
    h_off, s_off = _serve(
        cfg, params, tables, timing, hedge_reqs, batch, 2, hedge_timeout=None
    )
    hedge_bit_equal = all(np.array_equal(a, b) for a, b in zip(h_on, h_off))
    bit_equal &= hedge_bit_equal
    bit_equal &= all(
        np.array_equal(a, b) for a, b in zip(h_off, scores[2])
    )

    # ----------------------------------- 3+4. simulator overlap calibration
    util = sweep[2]["utilization"]
    target_util = float(np.mean(util))
    cal = calibrate_to_engine(
        util,
        n_batches=150 if smoke else 300,
        n_engines=4,
        n_units=4,
        inflight=2,  # the sim's outstanding batches == pipeline_depth 2
        # The ISSUE's acceptance is RELATIVE (within 10% of the measured
        # utilization), and in the wire-emulated regime the posting
        # occupancy is ~1e-3 — so the bisection tolerance must be scaled
        # to the target or the default absolute 0.02 stops on iteration 1.
        tol=0.05 * max(target_util, 1e-3),
        # Match the engine's (emulated) wire regime, or the bisection hunts
        # a posting cost in the wrong latency decade.
        t_server=timing.t_server,
        wire_bps=timing.wire_bps,
    )
    sim = compare_pipeline(
        depths=(1, 2), n_batches=150 if smoke else 400, t_dense=30e-6
    )

    return {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "throughput_rps": {d: sweep[d]["throughput_rps"] for d in DEPTHS},
        "lookup_stall_s": {d: sweep[d]["lookup_stall_s"] for d in DEPTHS},
        "pipeline_speedup": speedup,
        "bit_equal": bit_equal,
        "hedge_bit_equal": hedge_bit_equal,
        "hedged_batches": s_on["hedged_batches"],
        "hedged_wrs": s_on["engine"]["hedged"],
        "hedge_cancelled_wrs": s_on["engine"]["hedge_cancelled"],
        "utilization_depth2": [float(u) for u in util],
        "sim_pipeline_speedup": sim["speedup"],
        "sim_overlap_utilization_gain": sim["overlap_utilization_gain"],
        "calibrated_t_post_us": 1e6 * cal["t_post"],
        "calibration_target_util": cal["target_utilization"],
        "calibration_achieved_util": cal["achieved_utilization"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale configuration (CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    opts = ap.parse_args(argv)
    out = run(seed=opts.seed, smoke=opts.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bit_equal"]:
        raise SystemExit(
            "depth/hedge invariance VIOLATED: scores moved with the schedule"
        )
    if out["pipeline_speedup"] < 1.3:
        raise SystemExit(
            f"pipelining regressed: depth-2 speedup "
            f"{out['pipeline_speedup']:.2f}x < 1.3x"
        )
    if out["hedged_wrs"] <= 0:
        raise SystemExit("forced hedge issued no duplicate subrequests")
    target = out["calibration_target_util"]
    err = abs(out["calibration_achieved_util"] - target)
    # The ISSUE acceptance: simulator-predicted overlap within 10% of the
    # measured engine-pool utilization (relative — an absolute threshold
    # would be vacuous against the ~1e-3 occupancy of this wire regime).
    if err > 0.10 * max(target, 1e-6):
        raise SystemExit(
            f"simulator overlap calibration off by {err:.2e} utilization "
            f"(> 10% of the measured {target:.2e}): the virtual model no "
            "longer tracks the engine pool"
        )


if __name__ == "__main__":
    main()
