"""Paper Fig 8: (left) mapping-aware multi-threaded lookup vs naive — target
"up to 2.3x" throughput; (right) priority credit channel vs shared channel —
target ~35% lower credit latency.  Plus the live-migration ablation (§3.2).
"""
from __future__ import annotations

import time

from repro.core.flow_control import compare_credit_paths
from repro.runtime.simulator import compare_engines, compare_migration


def run() -> dict:
    t0 = time.perf_counter()
    eng = compare_engines(n_batches=1500)
    mig = compare_migration(n_batches=1500, n_units=8)
    credit = compare_credit_paths(num_responses=1024)
    credit_reduction = 1 - (
        credit["flexemr"]["mean_credit_latency"]
        / credit["strawman"]["mean_credit_latency"]
    )
    return {
        "us_per_call": 1e6 * (time.perf_counter() - t0),
        "engine_speedup": eng["speedup"],
        "naive_kbatches_s": eng["naive"]["throughput_batches_per_s"] / 1e3,
        "aware_kbatches_s": eng["flexemr"]["throughput_batches_per_s"] / 1e3,
        "migration_speedup": mig["speedup"],
        "credit_latency_reduction": credit_reduction,
    }


if __name__ == "__main__":
    print(run())
