import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=512", ""
    )
).strip()

"""§Perf hillclimb driver: lower a MODIFIED config for one of the three
selected (arch x shape) cells, re-analyze the roofline terms, and append the
iteration record to experiments/hillclimb/<name>.json.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell wide-deep-train --variant mesh2d
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "hillclimb"


def _to_sh(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(build, mesh):
    with mesh:
        jitted = jax.jit(
            build.step_fn,
            in_shardings=_to_sh(mesh, build.in_shardings),
            donate_argnums=build.donate_argnums,
        )
        compiled = jitted.lower(*build.args).compile()
        mem = compiled.memory_analysis()
    n_dev = int(np.prod(list(mesh.shape.values())))
    terms = hlo_analysis.analyze(compiled.as_text(), n_dev)
    gib = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes
    ) / 2**30
    return terms, gib


# --------------------------------------------------------------- cell builds


def wide_deep_train(variant: str):
    from repro.configs.recsys_common import build_recsys_cell
    from repro.configs.wide_deep import make_config

    cfg = make_config()
    if variant == "baseline-paper-fig4a":
        cfg = dataclasses.replace(cfg, mode="baseline")
    elif variant == "hierarchical":
        pass  # the paper-faithful default
    elif variant == "mesh2d":
        cfg = dataclasses.replace(cfg, mode="mesh2d")
    elif variant == "mesh2d-bf16comm":
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, mode="mesh2d", comm_dtype=jnp.bfloat16)
    elif variant == "mesh2d-bf16all":
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, mode="mesh2d", comm_dtype=jnp.bfloat16,
                                  compute_dtype=jnp.bfloat16)
    elif variant == "mesh2d-fusedwide":
        cfg = dataclasses.replace(cfg, mode="mesh2d", fuse_wide=True)
    elif variant == "hier-bf16comm":
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, comm_dtype=jnp.bfloat16)
    else:
        raise ValueError(variant)
    mesh = make_production_mesh()
    return build_recsys_cell(cfg, "train_batch", mesh, False), mesh


def llama_train(variant: str):
    import jax.numpy as jnp

    from repro.configs.lm_common import build_lm_cell
    from repro.configs.llama3_405b import CONFIG

    cfg = CONFIG
    if variant == "baseline":
        pass
    elif variant == "no-seqshard":
        cfg = dataclasses.replace(cfg, seq_shard=False)
    elif variant == "micro8":
        cfg = dataclasses.replace(cfg, microbatches=8)
    elif variant == "micro2":
        cfg = dataclasses.replace(cfg, microbatches=2)
    elif variant == "bf16grads":
        cfg = dataclasses.replace(cfg, bf16_grads=True)
    elif variant == "bf16grads-micro2":
        cfg = dataclasses.replace(cfg, bf16_grads=True, microbatches=2)
    elif variant == "noSP-micro8":
        cfg = dataclasses.replace(cfg, seq_shard=False, microbatches=8)
    elif variant == "qblock1024":
        cfg = dataclasses.replace(cfg, q_block=1024)
    else:
        raise ValueError(variant)
    mesh = make_production_mesh()
    return build_lm_cell(cfg, "adafactor", "train_4k", mesh, False, True), mesh


def products_train(variant: str):
    from repro.configs.graphsage_reddit import build_cell

    mesh = make_production_mesh()
    if variant == "baseline":
        return build_cell("ogb_products", mesh, False), mesh
    if variant == "partitioned":
        from benchmarks.gnn_partitioned import build_partitioned_cell

        return build_partitioned_cell(mesh, False), mesh
    if variant == "partitioned-pad128":
        from benchmarks.gnn_partitioned import build_partitioned_cell

        return build_partitioned_cell(mesh, False, pad_feat=128), mesh
    raise ValueError(variant)


def autoint_serve(variant: str):
    """Adaptive-cache field replication: small-vocab fields replicated on
    every chip leave the lookup collective statically (the controller's
    field-level plan, core/adaptive_cache.py)."""
    import dataclasses as _dc

    from repro.configs.autoint import make_config
    from repro.configs.recsys_common import build_recsys_cell

    cfg = make_config()
    if variant == "baseline":
        pass
    elif variant == "replicate-small":
        # the 26 x 100k-vocab fields fit a 166 MB replica budget
        cfg = _dc.replace(cfg, replicated_fields=tuple(range(13, 39)))
    elif variant == "replicate-small-mid":
        # + the 10 x 1M fields (806 MB total replicas)
        cfg = _dc.replace(cfg, replicated_fields=tuple(range(3, 39)))
    elif variant == "chunked4":
        cfg = _dc.replace(cfg, num_chunks=4)
    else:
        raise ValueError(variant)
    mesh = make_production_mesh()
    return build_recsys_cell(cfg, "serve_p99", mesh, False), mesh


CELLS = {
    "wide-deep-train": wide_deep_train,
    "autoint-serve": autoint_serve,
    "llama3-train": llama_train,
    "products-train": products_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", required=True)
    args = ap.parse_args()
    build, mesh = CELLS[args.cell](args.variant)
    terms, gib = lower_cell(build, mesh)
    rec = {
        "cell": args.cell,
        "variant": args.variant,
        "roofline": terms.as_dict(),
        "gib_per_dev": gib,
    }
    OUT.mkdir(parents=True, exist_ok=True)
    f = OUT / f"{args.cell}.json"
    hist = json.loads(f.read_text()) if f.exists() else []
    hist = [h for h in hist if h["variant"] != args.variant] + [rec]
    f.write_text(json.dumps(hist, indent=1))
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
