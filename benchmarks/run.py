"""Benchmark entrypoint — one bench per paper figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's headline
quantity), then the full §Roofline table assembled from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run            # full sweep
  PYTHONPATH=src python -m benchmarks.run --smoke    # seconds-scale subset

``--smoke`` runs the fast regression subset — the hotcache, prefetch, rdma,
pipeline, dedup, pushdown, obs, and loadgen benches in their shrunk
configurations — so cache-, prefetch-, engine-, pipeline-, wire-dedup-,
pooling-pushdown-, observability-, and latency-under-load regressions show
up in the bench trajectory without paying for the full figure sweep.  ``--json PATH`` additionally writes each
bench's scalar metrics for ``tools/bench_history.py`` to gate against the
committed ``benchmarks/baselines/BENCH_*.json`` snapshots.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast regression subset "
                    "(hotcache/prefetch/rdma/pipeline/dedup)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write per-bench scalar metrics as JSON "
                    "(input for tools/bench_history.py)")
    opts = ap.parse_args(argv)
    rows = []
    bench_metrics: dict[str, dict] = {}

    def bench(name, fn, derive):
        try:
            out = fn()
            rows.append((name, out.get("us_per_call", 0.0), derive(out)))
            bench_metrics[name] = {
                k: v for k, v in out.items()
                if isinstance(v, (bool, int, float))
            }
            print(f"{name},{out.get('us_per_call', 0.0):.1f},{derive(out)}")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            rows.append((name, -1, "FAILED"))
            bench_metrics[name] = {"FAILED": True}
            print(f"{name},-1,FAILED")

    def write_json():
        if opts.json is None:
            return
        ok = all(r[2] != "FAILED" for r in rows)
        with open(opts.json, "w") as f:
            json.dump({"benches": bench_metrics, "ok": ok}, f,
                      indent=1, sort_keys=True)
            f.write("\n")

    print("name,us_per_call,derived")

    from benchmarks import (
        chaos_bench,
        dedup_bench,
        hotcache_bench,
        loadgen_bench,
        obs_bench,
        overload_bench,
        pipeline_bench,
        prefetch_bench,
        rdma_bench,
    )

    hotcache_derive = lambda o: (  # noqa: E731
        f"bytes_reduction={o['bytes_reduction']:.2f}x "
        f"hit_rate={o['hit_rate']:.2f} "
        f"flat_us={o['flat_slab_us']:.0f} hash_us={o['hash_cache_us']:.0f}"
    )
    prefetch_derive = lambda o: (  # noqa: E731
        f"hit {o['hit_rate_base']:.2f}->{o['hit_rate_prefetch']:.2f} "
        f"miss_bytes={o['miss_bytes_reduction']:.2f}x "
        f"useful={o['prefetch_useful_rate']:.2f} "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"kernel={'ok' if o['kernel_matches_ref'] else 'MISMATCH'}"
    )
    rdma_derive = lambda o: (  # noqa: E731
        f"p99_speedup={o['p99_speedup']:.2f}x "
        f"steal={o['steal_speedup']:.2f}x "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"calib_t_post={o['calibrated_t_post_us']:.2f}us"
    )
    pipeline_derive = lambda o: (  # noqa: E731
        f"depth2_speedup={o['pipeline_speedup']:.2f}x "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"hedge_cancelled={o['hedge_cancelled_wrs']} "
        f"calib_err="
        f"{abs(o['calibration_achieved_util'] - o['calibration_target_util']):.3f}"
    )
    dedup_derive = lambda o: (  # noqa: E731
        f"byte_reduction={o['byte_reduction_high_skew']:.2f}x "
        f"p99={o['p99_speedup_high_skew']:.2f}x "
        f"coalesced={o['coalesced_rows']} "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"sim_err={o['sim_rel_err']:.1%}"
    )
    obs_derive = lambda o: (  # noqa: E731
        f"overhead={o['overhead_frac']:.1%} "
        f"events={o['events']} "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"sums={'ok' if o['sum_consistent'] else 'INCONSISTENT'} "
        f"trace={'ok' if o['trace_valid'] else 'INVALID'}"
    )
    chaos_derive = lambda o: (  # noqa: E731
        f"fired={o['faults_fired']} "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"hangs={'none' if o['zero_hangs'] else 'HUNG'} "
        f"p99_tail={o['p99_inflation_tail']:.2f}x"
        f"{'' if o['p99_bounded'] else ' UNBOUNDED'} "
        f"replicated={o['rows_re_replicated']} moved={o['moved_rows']}"
    )
    pushdown_derive = lambda o: (  # noqa: E731
        f"byte_reduction={o['byte_reduction']:.2f}x "
        f"segments={o['pooled_segments']} "
        f"req_frac={o['request_frac_on']:.2f} "
        f"invariant={'ok' if o['bit_equal'] else 'VIOLATED'} "
        f"sim_err={o['sim_rel_err']:.1%}"
    )
    loadgen_derive = lambda o: (  # noqa: E731
        f"capacity={o['capacity_qps']:.0f}rps "
        f"p99_knee={o['p99_knee_ms']:.1f}ms "
        f"p99_over={o['p99_overload_ms']:.1f}ms "
        f"crowd_alerts={o['crowd_alerts']} "
        f"coverage_err={o['attr_coverage_err']:.2%} "
        f"gates={'ok' if o['gates_ok'] else 'FAILED:' + ','.join(o['gates_failed'])}"
    )
    overload_derive = lambda o: (  # noqa: E731
        f"goodput_ratio={o['goodput_ratio']:.2f}x "
        f"shed={o['shed']} "
        f"retry_amp={o['retry_amplification']:.3f} "
        f"degraded={o['grid_degraded_requests']} "
        f"gates={'ok' if o['gates_ok'] else 'FAILED:' + ','.join(o['gates_failed'])}"
    )

    if opts.smoke:
        bench(
            "hotcache_smoke",
            lambda: hotcache_bench.run(smoke=True),
            hotcache_derive,
        )
        bench(
            "prefetch_smoke",
            lambda: prefetch_bench.run(smoke=True),
            prefetch_derive,
        )
        bench(
            "rdma_smoke",
            lambda: rdma_bench.run(smoke=True),
            rdma_derive,
        )
        bench(
            "pipeline_smoke",
            lambda: pipeline_bench.run(smoke=True),
            pipeline_derive,
        )
        bench(
            "dedup_smoke",
            lambda: dedup_bench.run(smoke=True),
            dedup_derive,
        )
        from benchmarks import fig4_pooling_bytes

        bench(
            "pushdown_smoke",
            lambda: fig4_pooling_bytes.run_pushdown(smoke=True),
            pushdown_derive,
        )
        bench(
            "obs_smoke",
            lambda: obs_bench.run(smoke=True),
            obs_derive,
        )
        bench(
            "loadgen_smoke",
            lambda: loadgen_bench.run(smoke=True),
            loadgen_derive,
        )
        bench(
            "chaos_smoke",
            lambda: chaos_bench.run(smoke=True),
            chaos_derive,
        )
        bench(
            "overload_smoke",
            lambda: overload_bench.run(smoke=True),
            overload_derive,
        )
        write_json()
        failed = [r for r in rows if r[2] == "FAILED"]
        if failed:
            sys.exit(1)
        return

    from benchmarks import (
        fig2_embedding_dominance,
        fig4_pooling_bytes,
        fig7_cache_contention,
        fig8_rdma,
        kernel_bench,
    )

    bench(
        "fig2_embedding_dominance",
        fig2_embedding_dominance.run,
        lambda o: f"embedding_share={o['embedding_share']:.2f}",
    )
    bench(
        "fig4_pooling_bytes",
        fig4_pooling_bytes.run,
        lambda o: (
            f"host_reduction={o['host_reduction']:.2f}x "
            f"spmd_reduction={o.get('spmd_reduction', float('nan')):.2f}x"
        ),
    )
    bench(
        "fig7_cache_contention",
        fig7_cache_contention.run,
        lambda o: (
            f"adaptive_vs_large_static={o['speedup_vs_large_static']:.2f}x "
            f"adaptive_rows={o['adaptive_rows']}"
        ),
    )
    bench(
        "fig8_rdma",
        fig8_rdma.run,
        lambda o: (
            f"engine_speedup={o['engine_speedup']:.2f}x "
            f"credit_latency_reduction={o['credit_latency_reduction']:.0%} "
            f"migration={o['migration_speedup']:.2f}x"
        ),
    )
    bench(
        "kernel_baselines",
        kernel_bench.run,
        lambda o: f"attention_us={o['attention_us']:.0f}",
    )
    bench("hotcache", hotcache_bench.run, hotcache_derive)
    bench("prefetch", prefetch_bench.run, prefetch_derive)
    bench("rdma", rdma_bench.run, rdma_derive)
    bench("pipeline", pipeline_bench.run, pipeline_derive)
    bench("dedup", dedup_bench.run, dedup_derive)
    bench(
        "pushdown",
        lambda: fig4_pooling_bytes.run_pushdown(smoke=False),
        pushdown_derive,
    )
    bench("obs", obs_bench.run, obs_derive)
    bench("loadgen", lambda: loadgen_bench.run(smoke=False), loadgen_derive)
    bench("chaos", lambda: chaos_bench.run(smoke=False), chaos_derive)
    bench(
        "overload",
        lambda: overload_bench.run(smoke=False),
        overload_derive,
    )

    print()
    try:
        from benchmarks import roofline

        roofline.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()

    # §Perf hillclimb trajectories (if the driver has been run)
    import pathlib

    hc = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "hillclimb"
    if hc.exists():
        print("\n== §Perf hillclimb iterations (experiments/hillclimb) ==")
        for f in sorted(hc.glob("*.json")):
            print(f"-- {f.stem}")
            for r in json.loads(f.read_text()):
                t = r["roofline"]
                print(
                    f"   {r['variant']:22s} comp={t['compute_s']*1e3:10.2f}ms "
                    f"mem={t['memory_s']*1e3:10.2f}ms "
                    f"coll={t['collective_s']*1e3:10.2f}ms "
                    f"gib={r['gib_per_dev']:6.2f}"
                )

    write_json()
    failed = [r for r in rows if r[2] == "FAILED"]
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
