"""§Roofline: assemble the per-(arch x shape x mesh) table from the dry-run
artifacts (experiments/dryrun/*.json) + analytic MODEL_FLOPS.

Each row: three terms in seconds, dominant bottleneck, MODEL_FLOPS /
HLO_FLOPS ratio, and a one-line 'what would move the dominant term down'.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.model_flops import model_flops
from repro import configs

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

MOVE_HINTS = {
    ("recsys", "collective"): "shard tables over (data x model) so embedding "
    "grads stay local (kills the DP table all-reduce); bf16 lookup partials",
    ("recsys", "memory"): "fuse gather+pool (Pallas embedding_bag), bf16 rows",
    ("recsys", "compute"): "batch the interaction matmuls on the MXU",
    ("lm-dense", "collective"): "sequence-parallel RS/AG instead of TP "
    "all-reduce; overlap layer collectives with compute; bf16 grads",
    ("lm-dense", "memory"): "flash attention (Pallas) keeps scores in VMEM; "
    "fewer remat recomputes; bf16 master-weight streaming",
    ("lm-dense", "compute"): "already MXU-bound: raise per-chip batch",
    ("lm-moe", "collective"): "same as lm-dense + expert-parallel a2a instead "
    "of replicated-token psum",
    ("lm-moe", "memory"): "flash attention + chunked dispatch buffers",
    ("lm-moe", "compute"): "drop capacity factor / fuse expert GEMMs",
    ("gnn", "collective"): "shard nodes instead of replicating them; "
    "reduce-scatter the aggregation",
    ("gnn", "memory"): "cast messages bf16; segment-sum in one pass",
    ("gnn", "compute"): "MXU-align feature dims (pad 100->128)",
}


def load_rows(mesh: str = "16x16") -> list[dict]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        arch, shape = r["arch"], r["shape"]
        t = r["roofline"]
        try:
            kind = configs.get(arch).kind
        except KeyError:
            kind = "recsys"
        mf = model_flops(arch, shape) / r["n_devices"]
        hlo = max(t["flops_per_device"], 1.0)
        rows.append(
            {
                "arch": arch,
                "shape": shape,
                "mesh": r["mesh"],
                "step": r["step"],
                "compute_s": t["compute_s"],
                "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "dominant": t["dominant"],
                "bound_s": max(t["compute_s"], t["memory_s"], t["collective_s"]),
                "model_flops_per_dev": mf,
                "hlo_flops_per_dev": hlo,
                "useful_ratio": mf / hlo,
                "gib_per_dev": r["memory_analysis"].get("per_device_total", 0) / 2**30,
                "hint": MOVE_HINTS.get((kind, t["dominant"]), ""),
            }
        )
    return rows


def render(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':15s} {'mesh':8s} {'comp_ms':>9s} {'mem_ms':>10s} "
        f"{'coll_ms':>10s} {'bound':>10s} {'MF/HLO':>7s} {'GiB':>6s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:15s} {r['mesh']:8s} "
            f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:10.2f} "
            f"{r['collective_s']*1e3:10.2f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['gib_per_dev']:6.2f}"
        )
    return "\n".join(lines)


def main():
    for mesh in ("16x16", "2x16x16"):
        rows = load_rows(mesh)
        if rows:
            print(f"\n== Roofline table ({mesh}, {len(rows)} cells) ==")
            print(render(rows))
    rows = load_rows("16x16")
    if rows:
        worst = min(rows, key=lambda r: r["useful_ratio"])
        coll = max(rows, key=lambda r: r["collective_s"] / max(r["bound_s"], 1e-12)
                   if r["dominant"] == "collective" else 0)
        print("\nworst useful-FLOPs ratio:", worst["arch"], worst["shape"],
              f"{worst['useful_ratio']:.3f}")
        print("most collective-bound:", coll["arch"], coll["shape"])


if __name__ == "__main__":
    main()
