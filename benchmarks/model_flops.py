"""Analytic MODEL_FLOPS per (arch x shape): the 'useful work' numerator for
the roofline table's MODEL_FLOPS / HLO_FLOPS ratio.

Definitions (per the brief): dense LM train = 6*N*T, MoE = 6*N_active*T
(N = params touched per token, T = tokens).  Inference: 2*N*T.  Attention's
quadratic term is added explicitly (it is real model work, not waste):
train 12*L*H*dh*S*T? -> expressed as 6 * (2*S*D_attn) per token-pair walk.
Recsys/GNN get first-principles matmul counts.
"""
from __future__ import annotations

from repro import configs
from repro.configs.lm_common import LM_SHAPES
from repro.configs.recsys_common import RECSYS_SHAPES, N_CANDIDATES


def _lm_params_active(cfg) -> tuple[float, float]:
    """(N_total, N_active_per_token), excluding embeddings' one-hot matmuls."""
    D, dh = cfg.d_model, cfg.d_head
    H, Hkv, F, L = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.n_layers
    per_layer_dense = D * H * dh + 2 * D * Hkv * dh + H * dh * D
    ffn = 3 * D * F if (cfg.moe is None or cfg.moe_dense_residual) else 0
    n_active = per_layer_dense + ffn
    n_total = n_active
    if cfg.moe is not None:
        expert = 3 * D * cfg.moe.d_ff
        n_total += cfg.moe.num_experts * expert + D * cfg.moe.num_experts
        n_active += cfg.moe.top_k * expert + D * cfg.moe.num_experts
    head = 2 * cfg.vocab * D  # embed + lm head matmuls
    return L * n_total + head, L * n_active + head


def lm_model_flops(cfg, shape: str) -> float:
    info = LM_SHAPES[shape]
    S, B = info["seq"], info["batch"]
    _, n_active = _lm_params_active(cfg)
    attn_per_token = 2 * 2 * cfg.n_heads * cfg.d_head * S / 2  # causal avg S/2
    if info["kind"] == "train":
        T = S * B
        return 6.0 * (n_active + attn_per_token * 0) * T + 3 * 2 * attn_per_token * T * cfg.n_layers
    if info["kind"] == "prefill":
        T = S * B
        return 2.0 * n_active * T + 2 * attn_per_token * T * cfg.n_layers
    # decode: one token per sample, attention over the full cache
    T = B
    attn_decode = 2 * 2 * cfg.n_heads * cfg.d_head * S
    return 2.0 * n_active * T + attn_decode * T * cfg.n_layers


def recsys_model_flops(cfg, shape: str) -> float:
    info = RECSYS_SHAPES[shape]
    B = info["batch"] if info["kind"] != "retrieval" else N_CANDIDATES
    F, D = cfg.num_fields, cfg.embed_dim

    def mlp_flops(sizes):
        f = 0
        for a, b in zip(sizes[:-1], sizes[1:]):
            f += 2 * a * b
        return f

    per_sample = 0.0
    if cfg.arch == "dlrm":
        per_sample += mlp_flops((cfg.n_dense,) + cfg.bottom_mlp)
        per_sample += 2 * (F + 1) ** 2 * D  # dot interaction
        n_pairs = (F + 1) * (F + 2) // 2
        per_sample += mlp_flops((n_pairs + cfg.bottom_mlp[-1],) + cfg.mlp + (1,))
    elif cfg.arch == "wide_deep":
        per_sample += mlp_flops((F * D + cfg.n_dense,) + cfg.mlp + (1,))
    elif cfg.arch == "autoint":
        d_in = D
        for _ in range(cfg.attn_layers):
            per_sample += 2 * F * d_in * cfg.d_attn * 3  # qkv
            per_sample += 2 * F * F * cfg.d_attn * 2  # scores + av
            per_sample += 2 * F * d_in * cfg.d_attn  # residual proj
            d_in = cfg.d_attn
        per_sample += 2 * F * d_in
    elif cfg.arch == "two_tower":
        Fu = cfg.user_tables
        per_sample += mlp_flops((Fu * D,) + cfg.mlp)
        per_sample += mlp_flops(((F - Fu) * D,) + cfg.mlp)
        per_sample += 2 * cfg.mlp[-1]
    elif cfg.arch == "mind":
        per_sample += 2 * cfg.hist_len * D * D  # bilinear
        per_sample += cfg.capsule_iters * (
            2 * cfg.hist_len * cfg.n_interests * D * 2
        )
        per_sample += 2 * cfg.n_interests * D * D
    elif cfg.arch == "dcn":
        d0 = F * D + cfg.n_dense
        per_sample += cfg.n_cross * 2 * d0 * cfg.cross_rank * 2  # U,V mats
        per_sample += mlp_flops((d0,) + cfg.mlp)
        per_sample += 2 * (d0 + cfg.mlp[-1])
    elif cfg.arch == "deepfm":
        per_sample += 4 * F * D  # FM second order
        per_sample += mlp_flops((F * D + cfg.n_dense,) + cfg.mlp + (1,))
    # lookup gather-adds: 2 flops per (row, dim) summed
    nnz_total = sum(t.nnz for t in cfg.tables)
    per_sample += 2 * nnz_total * D
    mult = 3.0 if info["kind"] == "train" else 1.0
    if cfg.arch == "two_tower" and info["kind"] == "retrieval":
        # scoring one user against candidates
        return 2.0 * N_CANDIDATES * cfg.mlp[-1]
    if cfg.arch == "mind" and info["kind"] == "retrieval":
        # routing once for the user + per-candidate interest dots
        routing = (
            2 * cfg.hist_len * D * D
            + cfg.capsule_iters * 2 * cfg.hist_len * cfg.n_interests * D * 2
        )
        return routing + 2.0 * N_CANDIDATES * cfg.n_interests * D
    total = mult * per_sample * B
    if cfg.arch == "two_tower" and info["kind"] == "train":
        # in-batch sampled softmax: the BxB score matrix is model work
        total += 3.0 * 2.0 * B * B * cfg.mlp[-1]
    return total


def gnn_model_flops(shape_info: dict, d_hidden: int = 128, n_layers: int = 2) -> float:
    kind = shape_info["kind"]
    d = shape_info["d_feat"]
    if kind == "full":
        N, E = shape_info["n_nodes"], shape_info["n_edges"]
        f = 0.0
        d_in = d
        for _ in range(n_layers):
            f += 2 * E * d_in  # message gather-add
            f += 2 * N * d_in * d_hidden * 2  # self + neigh mats
            d_in = d_hidden
        f += 2 * N * d_hidden * shape_info["n_classes"]
        return 3.0 * f  # train
    if kind == "minibatch":
        tgt = shape_info["batch_nodes"]
        f1, f2 = shape_info["fanout"]
        n1, n2 = tgt * f1, tgt * f1 * f2
        nodes = tgt + n1 + n2
        f = 2 * (n2 + n1) * d + 2 * nodes * d * d_hidden * 2
        f += 2 * (n1 + tgt) * d_hidden + 2 * nodes * d_hidden * d_hidden * 2
        f += 2 * tgt * d_hidden * shape_info["n_classes"]
        return 3.0 * f
    # molecule
    G, n, e = shape_info["batch"], shape_info["n_nodes"], shape_info["n_edges"]
    f = G * (2 * e * d + 2 * n * d * d_hidden * 2
             + 2 * e * d_hidden + 2 * n * d_hidden * d_hidden * 2
             + 2 * d_hidden * shape_info["n_classes"])
    return 3.0 * f


def model_flops(arch_id: str, shape: str) -> float:
    arch = configs.get(arch_id)
    if arch.kind.startswith("lm"):
        import importlib

        mod = importlib.import_module(
            "repro.configs." + arch_id.replace("-", "_")
        )
        return lm_model_flops(mod.CONFIG, shape)
    if arch.kind == "recsys":
        import importlib

        mod = importlib.import_module(
            "repro.configs." + arch_id.replace("-", "_")
        )
        return recsys_model_flops(mod.make_config(), shape)
    if arch.kind == "gnn":
        from repro.configs.graphsage_reddit import SHAPES

        return gnn_model_flops(SHAPES[shape])
    raise ValueError(arch_id)
