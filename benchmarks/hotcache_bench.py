"""Hotcache bench: flat-slab vs hash-cache lookup, and bytes over the wire.

Three measurements, one per layer of the repro/hotcache subsystem:

  1. device lookup latency — jitted DisaggEmbedding.lookup with the seed's
     flat sorted-slab HotCacheState vs the open-addressing HashCacheState
     (same hot set, same traffic).  On TPU the hash path additionally fuses
     probe+gather+pool in one Pallas kernel; here the comparison is the data
     structure itself.
  2. wire bytes — TieredLookupService on zipf-skewed traffic vs the same
     batches with no cache: hit rate and the bytes-reduction factor
     (the ISSUE's >= 2x acceptance quantity, also asserted in tests).
  3. simulator sweep — runtime.simulator.compare_hit_rates: closed-loop
     lookup throughput as the cache hit rate rises (Fig-7/8-style axis).

``run(smoke=True)`` shrinks every dimension so `benchmarks/run.py --smoke`
can exercise the whole path in seconds.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import (
    DisaggEmbedding,
    make_cache_from_table,
    make_hash_cache_from_table,
)
from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.hotcache.miss_path import TieredLookupService
from repro.hotcache.policy import AdmissionPolicy
from repro.runtime.simulator import compare_hit_rates


def _time_jit(fn, *args, iters: int) -> float:
    out = fn(*args)  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(seed: int = 0, smoke: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    B = 32 if smoke else 128
    batches = 12 if smoke else 24
    iters = 5 if smoke else 30
    specs = (
        TableSpec("hist", 8_000 if smoke else 200_000, nnz=8),
        TableSpec("item", 4_000 if smoke else 50_000, nnz=4),
        TableSpec("geo", 512, nnz=1, pooling="mean"),
    )
    dim, shards = 32, 4
    emb = DisaggEmbedding(specs=specs, dim=dim, num_shards=shards)
    params = emb.init(jax.random.key(0))
    cap = 2048 if smoke else 16_384

    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    b = syn.recsys_batch(rng, specs, B, alpha=1.35)
    idx, msk = jnp.asarray(b["indices"]), jnp.asarray(b["mask"])

    # hot set = most popular fused rows (zipf -> small ids are hot)
    offs = emb.sharded.field_offsets_array()
    fused = b["indices"].astype(np.int64) + offs[None, :, None]
    hot_ids, counts = np.unique(fused[b["mask"]], return_counts=True)
    order = np.argsort(-counts)[:cap]
    hot_ids, hot_freqs = hot_ids[order], counts[order]

    flat = make_cache_from_table(emb, params, hot_ids, cap, mesh=mesh)
    hashed = make_hash_cache_from_table(
        emb, params, hot_ids, cap * 2, freqs=hot_freqs, mesh=mesh
    )

    look = jax.jit(
        lambda p, i, m, c: emb.lookup(p, i, m, mesh=mesh, cache=c)
    )
    flat_us = _time_jit(look, params, idx, msk, flat, iters=iters)
    hash_us = _time_jit(look, params, idx, msk, hashed, iters=iters)

    # ------------------------------------------------------------ wire bytes
    tables = make_fused_tables(specs, dim, shards)
    svc = HostLookupService(tables, np.asarray(params["table"]))
    tiered = TieredLookupService(
        svc,
        num_slots=cap * 2,
        policy=AdmissionPolicy(admission_threshold=1.5, max_swap_in=cap),
        refresh_every=2,
    )
    try:
        for _ in range(max(4, batches // 3)):  # warmup
            w = syn.recsys_batch(rng, specs, B, alpha=1.35)
            tiered.lookup(w["indices"], w["mask"])
        tiered.stats = type(tiered.stats)()
        for _ in range(batches):
            w = syn.recsys_batch(rng, specs, B, alpha=1.35)
            tiered.lookup(w["indices"], w["mask"])
        s = tiered.stats
    finally:
        svc.close()

    moved = s.bytes_network + s.bytes_swap_in
    # Fig-4(a) raw-row regime (512 KiB responses): the wire is the bottleneck,
    # which is where the cache's miss-rate byte scaling shows up end to end.
    sim = compare_hit_rates(
        hit_rates=(0.0, 0.9),
        n_batches=200 if smoke else 1000,
        bytes_per_subrequest=524288.0,
    )
    return {
        "us_per_call": hash_us,
        "flat_slab_us": flat_us,
        "hash_cache_us": hash_us,
        "hit_rate": s.hit_rate,
        "bytes_no_cache": s.bytes_no_cache,
        "bytes_moved": moved,
        "bytes_reduction": s.bytes_no_cache / max(1, moved),
        "sim_speedup_at_90pct_hit": sim["speedup_at_max_hit"],
    }


if __name__ == "__main__":
    print(run())
