"""Paper Fig 2: the embedding layer dominates EMR serving time.

Measured on the host disaggregated path (CPU DRAM embedding servers + jit'd
dense ranker) over the paper's DLRM at reduced scale with zipf traffic:
reports the fraction of per-batch time spent in embedding lookup vs dense NN.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sharding import make_fused_tables
from repro.data import synthetic as syn
from repro.launch.serve import make_serving_dlrm
from repro.models import recsys as R
from repro.runtime.serving import FlexEMRServer


def run(batch: int = 256, iters: int = 20, seed: int = 0) -> dict:
    cfg = make_serving_dlrm(scale=2.0)
    rng = np.random.default_rng(seed)
    params = R.init_params(cfg, jax.random.key(seed))
    tables = make_fused_tables(cfg.tables, cfg.embed_dim, 8)
    server = FlexEMRServer(cfg, params, tables, controller=None)
    try:
        b = syn.recsys_batch(rng, cfg.tables, batch, n_dense=cfg.n_dense)
        # warm up jit
        pooled = server._lookup(b["indices"], b["mask"])
        server._dense(jnp.asarray(pooled), jnp.asarray(b["dense"])).block_until_ready()
        t_emb = t_nn = 0.0
        for _ in range(iters):
            t0 = time.perf_counter()
            pooled = server._lookup(b["indices"], b["mask"])
            t1 = time.perf_counter()
            server._dense(
                jnp.asarray(pooled), jnp.asarray(b["dense"])
            ).block_until_ready()
            t_emb += t1 - t0
            t_nn += time.perf_counter() - t1
        share = t_emb / (t_emb + t_nn)
        return {
            "us_per_call": 1e6 * (t_emb + t_nn) / iters,
            "embedding_share": share,
            "emb_ms": 1e3 * t_emb / iters,
            "nn_ms": 1e3 * t_nn / iters,
        }
    finally:
        server.close()


if __name__ == "__main__":
    print(run())
