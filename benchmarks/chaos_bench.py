"""Chaos bench: fault injection + live reshard under traffic, with gates.

Replays one request stream twice through the pipelined FlexEMRServer —
fault-free, then under a fixed four-fault schedule (engine-thread kill,
shard drop + restore, straggler storm, live reshard) — and gates the
recovery story the ISSUE demands:

  1. **bit_equal** — retired scores under chaos are bit-identical to the
     fault-free run.  Faults move WRs between threads, serve hot rows from
     cache replicas, park cold rows, and swap the shard map mid-stream;
     none of it may change a single output bit.
  2. **zero_hangs** — every batch retires, no watchdog force-restore was
     needed, and nothing is left parked in the engine pool at the end.
  3. **p99_bounded** — the *virtual* per-batch lookup p99 over the
     post-recovery tail is within ``P99_RECOVERY_BOUND`` of the fault-free
     run's: degradation must not outlive its fault.  (Virtual latencies
     come from the deterministic verbs schedule, so this gate does not
     flake with host noise; the mid-storm inflation is reported as
     ``p99_inflation_during`` but only the tail is gated.)

Both replays drive admit/retire explicitly (no wall-clock early-retire
heuristics), so the fault firing sequence and the virtual timeline are a
pure function of the seed.

``run(smoke=True)`` is the CI entry (`benchmarks/run.py --smoke`,
``python -m benchmarks.chaos_bench --smoke``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

P99_RECOVERY_BOUND = 3.0  # post-recovery virtual p99 <= bound * fault-free


def _build(seed: int):
    import jax

    from repro.core.sharding import TableSpec, make_fused_tables
    from repro.models import recsys as R

    tables_spec = (
        TableSpec("big", 4000, nnz=4),
        TableSpec("mid", 1000, nnz=2),
        TableSpec("small", 64, nnz=1),
    )
    cfg = R.RecsysConfig(
        name="chaos-bench", arch="dlrm", tables=tables_spec,
        embed_dim=16, n_dense=13, bottom_mlp=(64, 16), mlp=(64, 32),
    )
    params = R.init_params(cfg, jax.random.key(seed))
    tables = make_fused_tables(cfg.tables, cfg.embed_dim, 4)
    return cfg, params, tables


def _request_stream(rng, cfg, n_batches: int, batch: int) -> list[dict]:
    from repro.data import synthetic as syn

    reqs = []
    for _ in range(n_batches * batch):
        b = syn.recsys_batch(rng, cfg.tables, 1, n_dense=cfg.n_dense)
        reqs.append(
            {"indices": b["indices"][0], "mask": b["mask"][0],
             "dense": b["dense"][0]}
        )
    return reqs


def _schedule(num_shards: int, n_batches: int):
    """The fixed fault plan: one of each kind, recoveries inside the run."""
    from repro.chaos import FaultSchedule, FaultSpec

    q = n_batches // 6
    return FaultSchedule(faults=(
        FaultSpec("kill_engine", at_batch=q, target=1),
        FaultSpec("drop_shard", at_batch=2 * q, target=0,
                  duration_batches=2),
        FaultSpec("straggler_storm", at_batch=3 * q, target=1,
                  duration_batches=2, latency_mult=8.0),
        FaultSpec("reshard", at_batch=4 * q, target=num_shards * 2),
    ), seed=0)


def _serve(cfg, params, tables, reqs, batch, chaos=None):
    """Explicit admit/retire drive (deterministic batch clock); returns
    (scores per batch, virtual per-batch lookup latencies, summaries)."""
    from repro.core.adaptive_cache import AdaptiveCacheController, MemoryModel
    from repro.data.pipeline import BucketBatcher
    from repro.runtime.serving import FlexEMRServer

    controller = AdaptiveCacheController(
        cfg.tables, cfg.embed_dim,
        MemoryModel(fixed_bytes=1 << 20, bytes_per_sample=1 << 10,
                    hbm_bytes=1 << 28),
        field_replication=False, max_rows=1024,
    )
    server = FlexEMRServer(
        cfg, params, tables, controller=controller,
        cache_refresh_every=4, pipeline_depth=2, hedge_timeout=0.05,
        batcher=BucketBatcher(buckets=(batch,), max_wait=0.001),
        chaos=chaos,
    )
    try:
        for r in reqs:
            server.submit(r)
        outs = []
        while True:
            while len(server._pipeline) < server.pipeline_depth \
                    and server._admit_next():
                pass
            if not server._pipeline:
                break
            outs.append(server._retire_oldest()["scores"])
        vlat = list(server.service.virtual_latencies)
        engine = server.engine_summary()
        chaos_summary = None if chaos is None else chaos.summary()
    finally:
        server.close()
    return outs, vlat, engine, chaos_summary


def run(seed: int = 0, smoke: bool = False) -> dict:
    from repro.chaos import ChaosInjector

    t_start = time.perf_counter()
    n_batches = 24 if smoke else 48
    batch = 16
    cfg, params, tables = _build(seed)
    rng = np.random.default_rng(seed)
    reqs = _request_stream(rng, cfg, n_batches, batch)

    ref, vlat_ref, _, _ = _serve(cfg, params, tables, reqs, batch)
    injector = ChaosInjector(
        _schedule(tables.num_shards, n_batches), watchdog_s=10.0
    )
    outs, vlat, engine, summ = _serve(
        cfg, params, tables, reqs, batch, chaos=injector
    )

    bit_equal = len(outs) == len(ref) and all(
        np.array_equal(a, b) for a, b in zip(outs, ref)
    )
    zero_hangs = (
        len(outs) == n_batches
        and summ["wall"]["forced_restores"] == 0
        and engine["parked_now"] == 0
        and summ["active_drops"] == []
    )
    # Virtual p99s: whole-run inflation (reported) vs post-recovery tail
    # (gated).  The tail starts after the last fault's recovery window.
    tail = max(4, n_batches // 4)
    p99_ref = float(np.percentile(vlat_ref, 99))
    p99_during = float(np.percentile(vlat, 99))
    p99_tail_ref = float(np.percentile(vlat_ref[-tail:], 99))
    p99_tail = float(np.percentile(vlat[-tail:], 99))
    p99_bounded = p99_tail <= P99_RECOVERY_BOUND * max(p99_tail_ref, 1e-12)

    return {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "batches": n_batches,
        "bit_equal": bit_equal,
        "zero_hangs": zero_hangs,
        "p99_bounded": p99_bounded,
        "faults_fired": summ["faults_fired"],
        "faults_skipped": summ["faults_skipped"],
        "restores": summ["restores"],
        "reshards": summ["reshards"],
        "rows_re_replicated": summ["rows_re_replicated"],
        "moved_rows": summ["moved_rows"],
        "inflight_invalidated": summ["inflight_invalidated"],
        "killed_threads": engine["killed_threads"],
        "wrs_redealt": engine["wrs_redealt"],
        "wrs_parked": engine["wrs_parked"],
        "parked_released": engine["parked_released"],
        "p99_virtual_ref_us": 1e6 * p99_ref,
        "p99_inflation_during": p99_during / max(p99_ref, 1e-12),
        "p99_inflation_tail": p99_tail / max(p99_tail_ref, 1e-12),
        "forced_restores": summ["wall"]["forced_restores"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale configuration (CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    opts = ap.parse_args(argv)
    out = run(seed=opts.seed, smoke=opts.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bit_equal"]:
        raise SystemExit(
            "chaos invariance VIOLATED: scores moved under fault injection"
        )
    if not out["zero_hangs"]:
        raise SystemExit(
            "chaos recovery FAILED: hung/parked work or watchdog restores"
        )
    if not out["p99_bounded"]:
        raise SystemExit(
            f"chaos recovery p99 unbounded: tail inflation "
            f"{out['p99_inflation_tail']:.2f}x > {P99_RECOVERY_BOUND}x"
        )
    if out["faults_fired"] < 4:
        raise SystemExit(
            f"chaos schedule under-fired: {out['faults_fired']} < 4"
        )


if __name__ == "__main__":
    main()
