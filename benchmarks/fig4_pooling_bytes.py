"""Paper Fig 4: hierarchical pooling cuts embedding bytes on the network.

Three measurements:
  (a) host wire format — raw rows (4a) vs pushed-down partials (4b) bytes for
      zipf multi-hot traffic (HostLookupService.network_bytes);
  (b) SPMD collective bytes — baseline vs hierarchical DisaggEmbedding modes,
      parsed from compiled HLO of a small sharded lookup (the TPU-native
      restatement: the psum payload drops from [B,F,nnz,D] to [B,F,D]);
  (c) serving-path segment pushdown A/B (``run_pushdown``) — the SAME
      multi-hot zipf stream served by ``PooledLookupService`` with
      near-memory bag reduction on vs off, gated on:

        * bit-equal outputs (the partial-sum merge never perturbs results,
          including across pipeline depth 2 and a forced hedge);
        * response wire-byte reduction >= 2x (engine
          ``wire_response_bytes`` counters, not a format estimate);
        * ``runtime.simulator.compare_pushdown`` fed the *measured*
          poolable fraction and rows-per-segment predicting the measured
          byte reduction within 10% (relative) — the same closed-loop
          crosscheck dedup_bench runs, now for the pushdown model and the
          request-direction channel it exposes.

``python -m benchmarks.fig4_pooling_bytes --smoke`` runs only (c) in a
seconds-scale configuration with the gates enforced (the CI entry);
``benchmarks/run.py --smoke`` ingests the same dict as ``pushdown_smoke``.
"""
from __future__ import annotations

import argparse
import collections
import subprocess
import sys
import time

import numpy as np

from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn
from repro.rdma import PooledLookupService
from repro.runtime.simulator import compare_pushdown

SPMD_PROBE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.core.sharding import TableSpec
from repro.core.embedding import DisaggEmbedding
from repro.launch.hlo_analysis import analyze
mesh = make_mesh((2, 4), ("data", "model"))
specs = [TableSpec(f"t{i}", 100_000, nnz=8) for i in range(8)]
out = {}
for mode in ("baseline", "hierarchical"):
    emb = DisaggEmbedding(specs=specs, dim=64, num_shards=4, mode=mode)
    SDS = jax.ShapeDtypeStruct
    p = {"table": SDS((emb.sharded.total_rows, 64), jnp.float32)}
    idx = SDS((256, 8, 8), jnp.int32); msk = SDS((256, 8, 8), jnp.bool_)
    sh = lambda s: NamedSharding(mesh, s)
    comp = jax.jit(
        lambda p, i, m: emb.lookup(p, i, m, mesh=mesh),
        in_shardings=({"table": sh(P("model", None))}, sh(P("data", None, None)),
                      sh(P("data", None, None))),
    ).lower(p, idx, msk).compile()
    out[mode] = analyze(comp.as_text(), 8).collective_bytes_per_device
print(json.dumps(out))
"""


def run(batch: int = 1024, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    specs = tuple(TableSpec(f"t{i}", 100_000, nnz=8) for i in range(8))
    tables = make_fused_tables(specs, 64, 8)
    table = rng.normal(size=(tables.total_rows, 64)).astype(np.float32)
    b = syn.recsys_batch(rng, specs, batch)
    svc_raw = HostLookupService(tables, table, pushdown=False)
    svc_pd = HostLookupService(tables, table, pushdown=True)
    t0 = time.perf_counter()
    try:
        raw = svc_raw.network_bytes(b["indices"], b["mask"])
        pd = svc_pd.network_bytes(b["indices"], b["mask"])
    finally:
        svc_raw.close()
        svc_pd.close()

    import json
    import os
    import pathlib

    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_PROBE], env=env, capture_output=True,
        text=True, timeout=560,
    )
    spmd = json.loads(proc.stdout.strip().splitlines()[-1]) if proc.returncode == 0 else {}
    out = {
        "us_per_call": 1e6 * (time.perf_counter() - t0),
        "host_raw_bytes": raw,
        "host_pushdown_bytes": pd,
        "host_reduction": raw / max(pd, 1),
    }
    if spmd:
        out["spmd_baseline_coll_bytes"] = spmd["baseline"]
        out["spmd_hierarchical_coll_bytes"] = spmd["hierarchical"]
        out["spmd_reduction"] = spmd["baseline"] / max(spmd["hierarchical"], 1)
    return out


def _replay(tables, tnp, stream, segments: bool, depth: int = 1,
            hedge=None):
    """Serve the stream with ``depth`` lookups in flight; returns
    (outs, engine summary)."""
    svc = PooledLookupService(
        tables, tnp, num_threads=4, pushdown=True, dedup=True,
        pushdown_segments=segments,
    )
    outs = [None] * len(stream)
    try:
        pending: collections.deque = collections.deque()
        for i, b in enumerate(stream):
            pending.append(
                (i, svc.lookup_async(b["indices"], b["mask"],
                                     hedge_timeout=hedge))
            )
            if len(pending) >= depth:
                j, h = pending.popleft()
                outs[j] = h.wait()
        while pending:
            j, h = pending.popleft()
            outs[j] = h.wait()
        summary = svc.engine_summary()
    finally:
        svc.close()
    return outs, summary


def run_pushdown(seed: int = 0, smoke: bool = False) -> dict:
    """Measurement (c): serving-path segment-pushdown A/B (see module doc)."""
    t_start = time.perf_counter()
    n_batches = 8 if smoke else 32
    batch = 64
    # Multi-hot zipf: big-vocab tails keep most ids exclusive (poolable);
    # the duplicated zipf head stays on the dedup path — the composition
    # the serving default runs.
    specs = (
        TableSpec("hist", 200_000, nnz=32),
        TableSpec("item", 100_000, nnz=16),
    )
    dim, shards = 64, 4
    tables = make_fused_tables(specs, dim, shards)
    rng = np.random.default_rng(seed)
    tnp = (0.05 * rng.normal(size=(tables.total_rows, dim))).astype(
        np.float32
    )
    stream = [
        syn.recsys_batch(rng, specs, batch, alpha=1.05, cooccur_frac=0.1)
        for _ in range(n_batches)
    ]

    # ------------------------------------------------ A/B: same stream
    outs_off, s_off = _replay(tables, tnp, stream, segments=False)
    outs_on, s_on = _replay(tables, tnp, stream, segments=True)
    bit_equal = all(np.array_equal(x, y) for x, y in zip(outs_off, outs_on))
    # ... and under the pipelined + force-hedged serving shape.
    o2, _ = _replay(tables, tnp, stream[: max(4, n_batches // 2)],
                    segments=True, depth=2, hedge=0.0)
    bit_equal &= all(np.array_equal(x, y) for x, y in zip(o2, outs_off))

    byte_reduction = s_off["wire_response_bytes"] / max(
        1, s_on["wire_response_bytes"]
    )
    # Request bytes don't shrink: pushdown still posts every scattered id,
    # so the request share of the wire grows with the reduction.
    req_frac_off = s_off["wire_request_bytes"] / max(
        1, s_off["wire_response_bytes"]
    )
    req_frac_on = s_on["wire_request_bytes"] / max(
        1, s_on["wire_response_bytes"]
    )

    # ------------------------------- simulator crosscheck (within 10%)
    entry = 4 + dim * 4
    entries_off = s_off["wire_response_bytes"] / entry
    poolable_frac = s_on["pooled_rows"] / max(1.0, entries_off)
    rows_per_segment = s_on["pooled_rows"] / max(1, s_on["pooled_segments"])
    sim = compare_pushdown(
        poolable_frac=min(1.0, poolable_frac),
        rows_per_segment=rows_per_segment,
        request_bytes_per_subrequest=8.0
        * s_on["pooled_rows"] / max(1, s_on["pooled_segment_wrs"]),
        n_batches=150 if smoke else 400,
    )
    sim_err = abs(sim["byte_reduction"] - byte_reduction) / byte_reduction

    return {
        "us_per_call": 1e6 * (time.perf_counter() - t_start),
        "bit_equal": bit_equal,
        "byte_reduction": byte_reduction,
        "response_bytes_off": s_off["wire_response_bytes"],
        "response_bytes_on": s_on["wire_response_bytes"],
        "request_bytes_on": s_on["wire_request_bytes"],
        "request_frac_off": req_frac_off,
        "request_frac_on": req_frac_on,
        "pooled_segment_wrs": s_on["pooled_segment_wrs"],
        "pooled_segments": s_on["pooled_segments"],
        "pooled_rows": s_on["pooled_rows"],
        "poolable_frac": poolable_frac,
        "rows_per_segment": rows_per_segment,
        "sim_byte_reduction": sim["byte_reduction"],
        "sim_request_fraction": sim["request_fraction"],
        "sim_rel_err": sim_err,
    }


def gate_pushdown(out: dict) -> None:
    """Raise SystemExit on any pushdown gate failure (CI entry)."""
    if not out["bit_equal"]:
        raise SystemExit(
            "pushdown invariance VIOLATED: outputs moved with near-memory "
            "bag reduction"
        )
    if out["byte_reduction"] < 2.0:
        raise SystemExit(
            f"pushdown response-byte reduction regressed: "
            f"{out['byte_reduction']:.2f}x < 2.0x on multi-hot zipf"
        )
    if out["pooled_segments"] <= 0:
        raise SystemExit("pushdown dead: no segments pooled")
    if out["sim_rel_err"] > 0.10:
        raise SystemExit(
            f"simulator pushdown model off by {out['sim_rel_err']:.1%} "
            "(> 10% of the measured byte reduction)"
        )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale pushdown A/B only, gates enforced "
                    "(CI entry)")
    ap.add_argument("--seed", type=int, default=0)
    opts = ap.parse_args(argv)
    if not opts.smoke:
        for k, v in run(seed=opts.seed).items():
            print(f"{k}: {v}")
    out = run_pushdown(seed=opts.seed, smoke=opts.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    gate_pushdown(out)


if __name__ == "__main__":
    main()
