"""Paper Fig 4: hierarchical pooling cuts embedding bytes on the network.

Two measurements:
  (a) host wire format — raw rows (4a) vs pushed-down partials (4b) bytes for
      zipf multi-hot traffic (HostLookupService.network_bytes);
  (b) SPMD collective bytes — baseline vs hierarchical DisaggEmbedding modes,
      parsed from compiled HLO of a small sharded lookup (the TPU-native
      restatement: the psum payload drops from [B,F,nnz,D] to [B,F,D]).
"""
from __future__ import annotations

import subprocess
import sys
import time

import numpy as np

from repro.core.lookup_engine import HostLookupService
from repro.core.sharding import TableSpec, make_fused_tables
from repro.data import synthetic as syn

SPMD_PROBE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.core.sharding import TableSpec
from repro.core.embedding import DisaggEmbedding
from repro.launch.hlo_analysis import analyze
mesh = make_mesh((2, 4), ("data", "model"))
specs = [TableSpec(f"t{i}", 100_000, nnz=8) for i in range(8)]
out = {}
for mode in ("baseline", "hierarchical"):
    emb = DisaggEmbedding(specs=specs, dim=64, num_shards=4, mode=mode)
    SDS = jax.ShapeDtypeStruct
    p = {"table": SDS((emb.sharded.total_rows, 64), jnp.float32)}
    idx = SDS((256, 8, 8), jnp.int32); msk = SDS((256, 8, 8), jnp.bool_)
    sh = lambda s: NamedSharding(mesh, s)
    comp = jax.jit(
        lambda p, i, m: emb.lookup(p, i, m, mesh=mesh),
        in_shardings=({"table": sh(P("model", None))}, sh(P("data", None, None)),
                      sh(P("data", None, None))),
    ).lower(p, idx, msk).compile()
    out[mode] = analyze(comp.as_text(), 8).collective_bytes_per_device
print(json.dumps(out))
"""


def run(batch: int = 1024, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    specs = tuple(TableSpec(f"t{i}", 100_000, nnz=8) for i in range(8))
    tables = make_fused_tables(specs, 64, 8)
    table = rng.normal(size=(tables.total_rows, 64)).astype(np.float32)
    b = syn.recsys_batch(rng, specs, batch)
    svc_raw = HostLookupService(tables, table, pushdown=False)
    svc_pd = HostLookupService(tables, table, pushdown=True)
    t0 = time.perf_counter()
    try:
        raw = svc_raw.network_bytes(b["indices"], b["mask"])
        pd = svc_pd.network_bytes(b["indices"], b["mask"])
    finally:
        svc_raw.close()
        svc_pd.close()

    import json
    import os
    import pathlib

    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_PROBE], env=env, capture_output=True,
        text=True, timeout=560,
    )
    spmd = json.loads(proc.stdout.strip().splitlines()[-1]) if proc.returncode == 0 else {}
    out = {
        "us_per_call": 1e6 * (time.perf_counter() - t0),
        "host_raw_bytes": raw,
        "host_pushdown_bytes": pd,
        "host_reduction": raw / max(pd, 1),
    }
    if spmd:
        out["spmd_baseline_coll_bytes"] = spmd["baseline"]
        out["spmd_hierarchical_coll_bytes"] = spmd["hierarchical"]
        out["spmd_reduction"] = spmd["baseline"] / max(spmd["hierarchical"], 1)
    return out


if __name__ == "__main__":
    print(run())
